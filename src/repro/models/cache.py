"""Decode caches for every mixer family (pytree NamedTuples).

``serve_step`` lowers ONE new token against a cache of ``seq_len`` — these
structures are what gets sharded by the decode sharding rules (KV sequence
dim over the data axis for `long_500k`, heads over the model axis).

Continuous-batching serving adds a second cache family: ``PagedKVCache``
is a physical **page arena** shared by every in-flight sequence, addressed
through a per-slot **block table** (slot → ordered physical page ids).
Long and short sequences draw from the same pool, so the arena can be
provisioned below ``n_slots × max_seq_len``; the host-side
``PageAllocator`` owns which pages are free.  Page 0 is the reserved
**null page**: freed/inactive slots point their whole block row at it, so
the compiled decode step can keep writing "their" keys without masking —
the writes land in garbage memory no live sequence can see.  That is what
makes join/leave a pure data change (no retrace).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class KVCache(NamedTuple):
    k: jnp.ndarray  # (B, S, H_kv, D)
    v: jnp.ndarray  # (B, S, H_kv, D)
    index: jnp.ndarray  # scalar int32 — number of valid positions


class MLACache(NamedTuple):
    """DeepSeek MLA latent cache: compressed KV + shared rope key."""

    c_kv: jnp.ndarray  # (B, S, kv_lora_rank)
    k_rope: jnp.ndarray  # (B, S, qk_rope_head_dim)
    index: jnp.ndarray


class MambaCache(NamedTuple):
    conv: jnp.ndarray  # (B, d_conv - 1, d_inner) — conv tail window
    ssm: jnp.ndarray  # (B, d_inner, d_state)


class MLSTMCache(NamedTuple):
    C: jnp.ndarray  # (B, H, Dk, Dv) matrix memory
    n: jnp.ndarray  # (B, H, Dk) normalizer
    m: jnp.ndarray  # (B, H) gate stabilizer


class SLSTMCache(NamedTuple):
    c: jnp.ndarray  # (B, d)
    n: jnp.ndarray  # (B, d)
    h: jnp.ndarray  # (B, d)
    m: jnp.ndarray  # (B, d)


def kv_cache_init(batch: int, seq: int, n_kv: int, head_dim: int, dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, seq, n_kv, head_dim), dtype),
        v=jnp.zeros((batch, seq, n_kv, head_dim), dtype),
        index=jnp.zeros((), jnp.int32),
    )


def mla_cache_init(batch: int, seq: int, kv_lora: int, rope_dim: int, dtype) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((batch, seq, kv_lora), dtype),
        k_rope=jnp.zeros((batch, seq, rope_dim), dtype),
        index=jnp.zeros((), jnp.int32),
    )


def mamba_cache_init(batch: int, d_conv: int, d_inner: int, d_state: int, dtype) -> MambaCache:
    return MambaCache(
        conv=jnp.zeros((batch, d_conv - 1, d_inner), dtype),
        ssm=jnp.zeros((batch, d_inner, d_state), jnp.float32),
    )


def mlstm_cache_init(batch: int, heads: int, dk: int, dv: int) -> MLSTMCache:
    return MLSTMCache(
        C=jnp.zeros((batch, heads, dk, dv), jnp.float32),
        n=jnp.zeros((batch, heads, dk), jnp.float32),
        m=jnp.full((batch, heads), -1e30, jnp.float32),
    )


def slstm_cache_init(batch: int, d: int) -> SLSTMCache:
    return SLSTMCache(
        c=jnp.zeros((batch, d), jnp.float32),
        n=jnp.zeros((batch, d), jnp.float32),
        h=jnp.zeros((batch, d), jnp.float32),
        m=jnp.full((batch, d), -1e30, jnp.float32),
    )


# ----------------------------------------------------------------------------
# Paged KV cache — the continuous-batching serving arena
# ----------------------------------------------------------------------------

#: physical page id every freed / inactive block-table entry points at;
#: never handed out by ``PageAllocator``, so masked writes are harmless
NULL_PAGE = 0


class PagedKVCache(NamedTuple):
    """Physical KV page arena for one layer.

    Unlike ``KVCache`` there is no per-sequence axis and no fill index:
    position is owned by the caller's block table + per-slot lengths
    (host-managed, passed as jit *arguments* so slot churn never
    retraces).
    """

    k: jnp.ndarray  # (n_pages, page_size, H_kv, D)
    v: jnp.ndarray  # (n_pages, page_size, H_kv, D)


def paged_kv_cache_init(
    n_pages: int, page_size: int, n_kv: int, head_dim: int, dtype
) -> PagedKVCache:
    return PagedKVCache(
        k=jnp.zeros((n_pages, page_size, n_kv, head_dim), dtype),
        v=jnp.zeros((n_pages, page_size, n_kv, head_dim), dtype),
    )


def paged_view(cache: PagedKVCache, block: jnp.ndarray):
    """Gather each slot's pages into a dense per-slot view.

    ``block``: (n_slots, pages_per_slot) physical page ids.  Returns
    ``(k, v)`` of shape (n_slots, pages_per_slot · page_size, H_kv, D) —
    the contiguous layout the decode-attention kernel wants; positions
    beyond a slot's length hold stale/null-page garbage and must be
    masked by the attention's ``valid_len``.
    """
    n_slots, pp = block.shape
    P = cache.k.shape[1]
    tail = cache.k.shape[2:]
    k = jnp.take(cache.k, block.reshape(-1), axis=0)
    v = jnp.take(cache.v, block.reshape(-1), axis=0)
    return (
        k.reshape(n_slots, pp * P, *tail),
        v.reshape(n_slots, pp * P, *tail),
    )


def paged_append(
    cache: PagedKVCache,
    block: jnp.ndarray,  # (n_slots, pages_per_slot)
    length: jnp.ndarray,  # (n_slots,) — tokens already stored per slot
    k_tok: jnp.ndarray,  # (n_slots, H_kv, D) — one new token per slot
    v_tok: jnp.ndarray,
) -> PagedKVCache:
    """Scatter one token per slot at its next logical position.

    Inactive slots need no masking: their block row is all ``NULL_PAGE``,
    so the write lands in the trash page (several inactive slots may
    collide there — by design).
    """
    P = cache.k.shape[1]
    page = jnp.take_along_axis(block, (length // P)[:, None], axis=1)[:, 0]
    off = length % P
    return PagedKVCache(
        k=cache.k.at[page, off].set(k_tok.astype(cache.k.dtype)),
        v=cache.v.at[page, off].set(v_tok.astype(cache.v.dtype)),
    )


def paged_write(
    cache: PagedKVCache,
    block_row: jnp.ndarray,  # (pages_per_slot,) — ONE slot's pages
    k_seq: jnp.ndarray,  # (S, H_kv, D) — prefilled keys, rows < n_valid real
    v_seq: jnp.ndarray,
    n_valid: jnp.ndarray,
) -> PagedKVCache:
    """Write a prefilled sequence into one slot's pages (the join path).

    Rows ≥ ``n_valid`` (prompt-bucket padding) are redirected to the null
    page instead of being masked out, so the scatter shape is static.
    """
    P = cache.k.shape[1]
    S = k_seq.shape[0]
    pos = jnp.arange(S)
    page = jnp.where(pos < n_valid, block_row[pos // P], NULL_PAGE)
    off = pos % P
    return PagedKVCache(
        k=cache.k.at[page, off].set(k_seq.astype(cache.k.dtype)),
        v=cache.v.at[page, off].set(v_seq.astype(cache.v.dtype)),
    )


class PageAllocator:
    """Host-side free-list allocator over a ``PagedKVCache`` arena.

    LIFO reuse keeps recently-freed (cache-warm) pages hot.  Page
    ``NULL_PAGE`` (0) is reserved and never allocated.  Invariants are
    enforced loudly: freeing a page that isn't live raises, allocation
    beyond capacity returns None (callers queue the request instead of
    corrupting a live slot).
    """

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("need ≥ 2 pages (page 0 is the null page)")
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, 0, -1))  # pop() yields 1, 2, …
        self._used: set = set()

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return len(self._used)

    def alloc(self, n: int) -> list | None:
        """``n`` physical page ids, or None if the arena can't supply them
        (all-or-nothing: a partial allocation is never handed out)."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._used.update(pages)
        return pages

    def free(self, pages) -> None:
        for p in pages:
            if p not in self._used:
                raise ValueError(
                    f"free() of page {p} which is not allocated "
                    f"(double free or foreign page)"
                )
            self._used.remove(p)
            self._free.append(p)
