"""Grouped-query attention with RoPE / M-RoPE, sliding windows, KV cache.

Reference (XLA) path; the Pallas flash kernel in
``repro.kernels.flash_attention`` is a drop-in for the train/prefill core
(``use_kernel=True`` on TPU), and the single-token decode path can route
through ``repro.kernels.decode_attention`` (``decode_attn="pallas"``) or
its bit-equal jitted XLA reference (``decode_attn="xla"`` — the explicit
fallback ``decode_kernel_plan`` reports).  ``decode_attn="off"`` keeps
the historical ``_sdpa`` decode math untouched.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.cache import KVCache, PagedKVCache, paged_append, paged_view
from repro.models.config import ModelConfig
from repro.models.layers import apply_mrope, apply_rope, dense, dense_init
from repro.sharding.rules import current_mesh_context, maybe_shard


def attn_init(key, cfg: ModelConfig, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": dense_init(kq, d, h * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": dense_init(kk, d, hkv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": dense_init(kv, d, hkv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": dense_init(ko, h * hd, d, dtype=dtype),
    }


def _sdpa(q, k, v, mask, *, scale):
    """Softmax attention core; fp32 logits/softmax regardless of input dtype.

    q: (B, T, H, D); k/v: (B, S, Hkv, D) with H = G*Hkv (GQA).
    mask: (B, T, S) or (T, S) boolean — True = attend.
    """
    B, T, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, T, Hkv, G, D)
    logits = jnp.einsum(
        "bthgd,bshd->bhgts", qg, k, preferred_element_type=jnp.float32
    )  # (B, Hkv, G, T, S)
    logits = logits * scale
    m = mask if mask.ndim == 3 else mask[None]
    logits = jnp.where(m[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bhgts,bshd->bthgd", probs.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, T, H, D).astype(q.dtype)


def _sdpa_q_chunked(q, k, v, *, scale, q_chunk: int, window: int = 0):
    """Causal attention scanned over query chunks — the XLA-path analogue of
    flash attention's memory behavior: only (B, H, q_chunk, S) logits are
    live at once.  q: (B, T, H, D); T must be a multiple of q_chunk."""
    B, T, H, D = q.shape
    nch = T // q_chunk
    qs = q.reshape(B, nch, q_chunk, H, D).swapaxes(0, 1)  # (nch, B, qc, H, D)

    def chunk(i, q_blk):
        mask = causal_mask(q_chunk, T, offset=i * q_chunk, window=window)
        return _sdpa(q_blk, k, v, mask, scale=scale)

    outs = jax.lax.map(lambda iq: chunk(iq[0], iq[1]), (jnp.arange(nch), qs))
    return outs.swapaxes(0, 1).reshape(B, T, H, D)


def resolve_decode_attn(use_kernel, *, sliding_window: int = 0) -> str:
    """Map the public ``use_kernel`` knob (True / False / "auto") to the
    static decode-attention implementation tag: "pallas" (the Pallas
    kernel — forced, or auto on TPU) or "xla" (the jitted reference,
    bit-equal to the kernel).  Sliding-window attention has no kernel
    path and raises rather than silently changing semantics."""
    if sliding_window > 0:
        raise ValueError(
            "decode_attention has no sliding-window support — serve "
            "sliding-window models with decode_attn='off'"
        )
    if use_kernel == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return "pallas" if use_kernel else "xla"


def decode_kernel_plan(cfg: ModelConfig, *, use_kernel="auto") -> dict:
    """Which implementation the single-token decode path will take, and
    why — the ``kernel_plan``-style report serving surfaces so a run
    claiming kernel speed can't silently be on the fallback."""
    if cfg.sliding_window > 0:
        return {
            "path": "off",
            "reason": f"sliding_window={cfg.sliding_window} (no kernel path)",
        }
    backend = jax.default_backend()
    path = resolve_decode_attn(use_kernel)
    if path == "pallas":
        reason = (
            "forced by use_kernel=True" if use_kernel is True
            else f"backend={backend}"
        )
        if backend != "tpu":
            reason += " (interpret mode)"
    else:
        reason = (
            f"backend={backend} — jitted XLA reference (bit-equal "
            "to the kernel)"
        )
    return {"path": path, "reason": reason, "backend": backend}


def _decode_attend(q1, k_all, v_all, valid_len, *, impl: str):
    """One-token attention over a dense cache view via the decode kernel
    ("pallas") or its bit-equal jitted reference ("xla").
    q1: (B, Hq, D); k/v: (B, S, Hkv, D); valid_len: (B,) or scalar."""
    from repro.kernels.decode_attention import ops as da_ops

    if impl == "pallas":
        return da_ops.decode_attention(q1, k_all, v_all, valid_len)
    if impl == "xla":
        return da_ops.decode_attention_xla(q1, k_all, v_all, valid_len)
    raise ValueError(f"unknown decode_attn impl {impl!r}")


def causal_mask(T: int, S: int, *, offset: int = 0, window: int = 0) -> jnp.ndarray:
    """(T, S) mask; query i attends key j iff j <= i+offset (and within the
    sliding window when ``window > 0``)."""
    qpos = jnp.arange(T)[:, None] + offset
    kpos = jnp.arange(S)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m


def attn_apply(
    p,
    cfg: ModelConfig,
    x: jnp.ndarray,
    *,
    positions: jnp.ndarray,
    cache: KVCache | PagedKVCache | None = None,
    mrope_positions: jnp.ndarray | None = None,
    use_kernel: bool = False,
    pages: tuple | None = None,
    decode_attn: str = "off",
):
    """GQA attention.  Train/prefill when ``cache is None``; otherwise decode:
    append x's (single or few) tokens at ``cache.index`` and attend over the
    full cache.

    A ``PagedKVCache`` cache decodes through the block table instead:
    ``pages=(block, length)`` (slot → page ids, per-slot fill counts) are
    jit arguments, the new token is scattered into the arena and
    attention runs on the gathered per-slot view via ``_decode_attend``.
    ``decode_attn`` ("off" | "xla" | "pallas") statically picks the
    single-token decode implementation; "off" keeps the ``_sdpa`` path.
    """
    B, T, d = x.shape
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    cd = x.dtype

    q = dense(p["wq"], x).reshape(B, T, H, D)
    k = dense(p["wk"], x).reshape(B, T, Hkv, D)
    v = dense(p["wv"], x).reshape(B, T, Hkv, D)

    if cfg.mrope_sections and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    scale = D ** -0.5

    if cache is None:
        if use_kernel and T >= 128:
            from repro.kernels.flash_attention import ops as fa_ops

            out = fa_ops.flash_attention(
                q, k, v, causal=True, window=cfg.sliding_window
            )
        else:
            qc = 0 if cfg.unroll_time_scans else cfg.attn_q_chunk
            if qc and T > qc and T % qc == 0:
                out = _sdpa_q_chunked(
                    q, k, v, scale=scale, q_chunk=qc, window=cfg.sliding_window
                )
            else:
                mask = causal_mask(T, T, window=cfg.sliding_window)
                out = _sdpa(q, k, v, mask, scale=scale)
        new_cache = None
    elif isinstance(cache, PagedKVCache):
        if T != 1:
            raise ValueError("paged decode appends exactly one token")
        if cfg.sliding_window > 0:
            raise ValueError("paged decode needs full causal attention")
        block, length = pages
        new_cache = paged_append(cache, block, length, k[:, 0], v[:, 0])
        k_all, v_all = paged_view(new_cache, block)
        impl = decode_attn if decode_attn != "off" else "xla"
        out = _decode_attend(
            q[:, 0], k_all.astype(cd), v_all.astype(cd), length + 1,
            impl=impl,
        )[:, None]  # (B, 1, Hq, D)
    else:
        S = cache.k.shape[1]
        idx = cache.index
        k_all = jax.lax.dynamic_update_slice(
            cache.k, k.astype(cache.k.dtype), (0, idx, 0, 0)
        )
        v_all = jax.lax.dynamic_update_slice(
            cache.v, v.astype(cache.v.dtype), (0, idx, 0, 0)
        )
        ctx = current_mesh_context()
        if ctx is not None and "kvseq" in ctx.logical:
            # keep the cache sequence-sharded through the attention compute
            # (flash-decode locality: partial softmax per shard + tiny
            # combine instead of all-gathering K/V)
            k_all = maybe_shard(k_all, "batch", "kvseq", None, None)
            v_all = maybe_shard(v_all, "batch", "kvseq", None, None)
        if decode_attn != "off" and T == 1 and cfg.sliding_window == 0:
            valid = jnp.broadcast_to(idx + 1, (B,))
            out = _decode_attend(
                q[:, 0], k_all.astype(cd), v_all.astype(cd), valid,
                impl=decode_attn,
            )[:, None]
        else:
            # valid keys: j <= idx + i (supports T >= 1 appended tokens)
            qpos = idx + jnp.arange(T)[:, None]
            kpos = jnp.arange(S)[None, :]
            mask = kpos <= qpos
            if cfg.sliding_window > 0:
                mask &= kpos > qpos - cfg.sliding_window
            out = _sdpa(q, k_all.astype(cd), v_all.astype(cd), mask, scale=scale)
        new_cache = KVCache(k=k_all, v=v_all, index=idx + T)

    y = dense(p["wo"], out.reshape(B, T, H * D))
    return y, new_cache
