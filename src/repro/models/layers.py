"""Core neural-net layers (pure-functional, dict-pytree parameters).

No flax/haiku dependency: each layer is an ``init(key, ...) -> params`` plus
an ``apply(params, x, ...) -> y`` pair.  Parameters are nested dicts whose
leaf *names* drive the sharding rules in ``repro.sharding.rules``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def truncated_normal(key, shape, dtype, stddev):
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape).astype(dtype)


# ----------------------------------------------------------------------------
# Dense
# ----------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, bias: bool = False, dtype=jnp.float32):
    stddev = (1.0 / d_in) ** 0.5
    p = {"kernel": truncated_normal(key, (d_in, d_out), dtype, stddev)}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x, *, compute_dtype=None):
    """Matmul in the activation dtype (params are cast down, not the
    activations up) — the standard bf16-compute / fp32-master convention."""
    k = p["kernel"]
    if compute_dtype is not None:
        x = x.astype(compute_dtype)
    k = k.astype(x.dtype)
    y = x @ k
    if "bias" in p:
        y = y + p["bias"].astype(y.dtype)
    return y


# ----------------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, *, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, *, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------------------
# Embedding
# ----------------------------------------------------------------------------

def embedding_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"embedding": truncated_normal(key, (vocab, d), dtype, 0.02)}


def embed(p, ids, *, compute_dtype=None):
    e = p["embedding"]
    out = jnp.take(e, ids, axis=0)
    if compute_dtype is not None:
        out = out.astype(compute_dtype)
    return out


def unembed(p, x):
    """Logits = x @ Eᵀ (tied) — fp32 accumulation for the softmax path."""
    return jnp.einsum(
        "...d,vd->...v", x, p["embedding"], preferred_element_type=jnp.float32
    )


# ----------------------------------------------------------------------------
# SwiGLU MLP (llama-family FFN)
# ----------------------------------------------------------------------------

def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype=dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype=dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype=dtype),
    }


def swiglu(p, x, *, compute_dtype=None):
    g = dense(p["w_gate"], x, compute_dtype=compute_dtype)
    u = dense(p["w_up"], x, compute_dtype=compute_dtype)
    return dense(p["w_down"], jax.nn.silu(g) * u, compute_dtype=compute_dtype)


# ----------------------------------------------------------------------------
# GELU MLP (whisper FFN)
# ----------------------------------------------------------------------------

def gelu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "w_in": dense_init(k1, d_model, d_ff, bias=True, dtype=dtype),
        "w_out": dense_init(k2, d_ff, d_model, bias=True, dtype=dtype),
    }


def gelu_mlp(p, x, *, compute_dtype=None):
    h = dense(p["w_in"], x, compute_dtype=compute_dtype)
    return dense(p["w_out"], jax.nn.gelu(h), compute_dtype=compute_dtype)


# ----------------------------------------------------------------------------
# Rotary position embeddings (standard + multimodal M-RoPE)
# ----------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotate ``x`` (..., T, H, D) by per-token ``positions`` (..., T)."""
    d = x.shape[-1]
    inv = rope_frequencies(d, theta)  # (D/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * inv  # (..., T, 1, D/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray, positions3: jnp.ndarray, theta: float, sections: tuple
) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL [arXiv:2409.12191]).

    ``positions3``: (3, ..., T) — temporal / height / width position ids.
    ``sections``: frequency-band split of head_dim/2, e.g. (16, 24, 24).
    Each band takes its rotation angle from the corresponding position id.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, "mrope sections must cover head_dim/2"
    inv = rope_frequencies(d, theta)  # (D/2,)
    # select which of the 3 position streams drives each frequency band
    band = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=d // 2
    )  # (D/2,) in {0,1,2}
    pos = positions3[band, ..., :]  # (D/2, ..., T) — gather per band
    pos = jnp.moveaxis(pos, 0, -1)  # (..., T, D/2)
    ang = pos[..., :, None, :].astype(jnp.float32) * inv  # (..., T, 1, D/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# Losses
# ----------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray, *, mask=None):
    """Mean token cross entropy; logits (..., V) fp32, labels (...) int."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
