"""Multi-head Latent Attention (MLA) — DeepSeek-V2/V3, MiniCPM3.

The KV cache stores only the compressed latent ``c_kv`` (kv_lora_rank) plus
one shared roped key per position — the MLA memory saving.  Two decode
paths:

* ``absorb=False`` (paper-faithful): up-project the whole cached latent to
  per-head K/V every step;
* ``absorb=True`` (the published inference optimization, used as a §Perf
  lever): absorb ``W_uk`` into the query and ``W_uv`` into the output so
  attention runs directly in the latent space — per-step FLOPs drop from
  O(S·H·d_nope·r) to O(S·(H·d_nope·r / S + r)) per head-dim terms; see
  EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.cache import MLACache
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense, dense_init, rmsnorm, rmsnorm_init


def mla_init(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.mla
    H = cfg.num_heads
    ks = jax.random.split(key, 7)
    return {
        "w_dq": dense_init(ks[0], cfg.d_model, m.q_lora_rank, dtype=dtype),
        "q_norm": rmsnorm_init(m.q_lora_rank, dtype),
        "w_uq": dense_init(
            ks[1], m.q_lora_rank, H * (m.qk_nope_head_dim + m.qk_rope_head_dim), dtype=dtype
        ),
        "w_dkv": dense_init(ks[2], cfg.d_model, m.kv_lora_rank, dtype=dtype),
        "kv_norm": rmsnorm_init(m.kv_lora_rank, dtype),
        "w_kr": dense_init(ks[3], cfg.d_model, m.qk_rope_head_dim, dtype=dtype),
        "w_uk": dense_init(ks[4], m.kv_lora_rank, H * m.qk_nope_head_dim, dtype=dtype),
        "w_uv": dense_init(ks[5], m.kv_lora_rank, H * m.v_head_dim, dtype=dtype),
        "w_o": dense_init(ks[6], H * m.v_head_dim, cfg.d_model, dtype=dtype),
    }


def _queries(p, cfg, x, positions):
    m, H = cfg.mla, cfg.num_heads
    B, T, _ = x.shape
    cq = rmsnorm(p["q_norm"], dense(p["w_dq"], x), eps=cfg.rms_eps)
    q = dense(p["w_uq"], cq).reshape(B, T, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_apply(
    p,
    cfg: ModelConfig,
    x: jnp.ndarray,
    *,
    positions: jnp.ndarray,
    cache: MLACache | None = None,
    absorb: bool = False,
    **_,
):
    m, H = cfg.mla, cfg.num_heads
    B, T, _ = x.shape
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5

    q_nope, q_rope = _queries(p, cfg, x, positions)
    c_kv_new = dense(p["w_dkv"], x)  # (B, T, r) — raw latent, cached
    k_rope_new = apply_rope(
        dense(p["w_kr"], x)[:, :, None, :], positions, cfg.rope_theta
    )[:, :, 0, :]  # (B, T, dr) shared across heads

    if cache is None:
        c_kv, k_rope = c_kv_new, k_rope_new
        S = T
        qpos = jnp.arange(T)[:, None]
        kpos = jnp.arange(S)[None, :]
        mask = kpos <= qpos
        new_cache = None
    else:
        idx = cache.index
        c_kv = jax.lax.dynamic_update_slice(
            cache.c_kv, c_kv_new.astype(cache.c_kv.dtype), (0, idx, 0)
        )
        k_rope = jax.lax.dynamic_update_slice(
            cache.k_rope, k_rope_new.astype(cache.k_rope.dtype), (0, idx, 0)
        )
        S = cache.c_kv.shape[1]
        qpos = idx + jnp.arange(T)[:, None]
        kpos = jnp.arange(S)[None, :]
        mask = kpos <= qpos
        new_cache = MLACache(c_kv=c_kv, k_rope=k_rope, index=idx + T)

    ckv_n = rmsnorm(p["kv_norm"], c_kv.astype(x.dtype), eps=cfg.rms_eps)  # (B, S, r)

    # rope-part logits are shared by both paths
    logits_rope = jnp.einsum(
        "bthd,bsd->bhts", q_rope, k_rope.astype(x.dtype),
        preferred_element_type=jnp.float32,
    )

    if not absorb:
        # paper-faithful: materialize per-head K/V from the latent
        k_nope = dense(p["w_uk"], ckv_n).reshape(B, S, H, m.qk_nope_head_dim)
        v = dense(p["w_uv"], ckv_n).reshape(B, S, H, m.v_head_dim)
        logits_nope = jnp.einsum(
            "bthd,bshd->bhts", q_nope, k_nope, preferred_element_type=jnp.float32
        )
        logits = (logits_nope + logits_rope) * scale
        logits = jnp.where(mask[None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum(
            "bhts,bshd->bthd", probs.astype(v.dtype), v,
            preferred_element_type=jnp.float32,
        )  # (B, T, H, dv)
    else:
        # absorbed: q_lat = q_nope @ W_uk  → attend in latent space
        # (fp32 operands: the 3-way bf16→f32 dot is unsupported on the CPU
        # interpret backend, and fp32 here matches the unabsorbed numerics)
        w_uk = p["w_uk"]["kernel"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
        q_lat = jnp.einsum(
            "bthd,rhd->bthr", q_nope.astype(jnp.float32), w_uk.astype(jnp.float32)
        )
        ckv32 = ckv_n.astype(jnp.float32)
        logits_nope = jnp.einsum("bthr,bsr->bhts", q_lat, ckv32)
        logits = (logits_nope + logits_rope) * scale
        logits = jnp.where(mask[None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        ctx_lat = jnp.einsum("bhts,bsr->bthr", probs, ckv32)  # (B, T, H, r)
        w_uv = p["w_uv"]["kernel"].reshape(m.kv_lora_rank, H, m.v_head_dim)
        out = jnp.einsum("bthr,rhd->bthd", ctx_lat, w_uv.astype(jnp.float32))

    y = dense(p["w_o"], out.astype(x.dtype).reshape(B, T, H * m.v_head_dim))
    return y, new_cache
