"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The mel-spectrogram + conv frontend is a STUB per the assignment brief:
``input_specs()`` provides precomputed frame embeddings (B, S_enc, d) — the
output the two conv layers would produce.  This module implements the
transformer backbone: bidirectional encoder, causal decoder with
cross-attention, learned positions, pre-LN, GELU FFNs (whisper uses
LayerNorm + GELU, not RMSNorm + SwiGLU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.cache import KVCache, kv_cache_init
from repro.models.config import ModelConfig
from repro.models.layers import (
    cross_entropy,
    dense,
    dense_init,
    embed,
    embedding_init,
    gelu_mlp,
    gelu_mlp_init,
    layernorm,
    layernorm_init,
    truncated_normal,
    unembed,
)
from repro.sharding.rules import maybe_shard


def _mha_init(key, cfg: ModelConfig, dtype):
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    return {
        "wq": dense_init(kq, d, h * hd, bias=True, dtype=dtype),
        "wk": dense_init(kk, d, h * hd, dtype=dtype),
        "wv": dense_init(kv, d, h * hd, bias=True, dtype=dtype),
        "wo": dense_init(ko, h * hd, d, bias=True, dtype=dtype),
    }


def _mha(p, cfg, xq, xkv, mask):
    B, T, _ = xq.shape
    S = xkv.shape[1]
    H, D = cfg.num_heads, cfg.head_dim
    q = dense(p["wq"], xq).reshape(B, T, H, D)
    k = dense(p["wk"], xkv).reshape(B, S, H, D)
    v = dense(p["wv"], xkv).reshape(B, S, H, D)
    logits = jnp.einsum("bthd,bshd->bhts", q, k, preferred_element_type=jnp.float32)
    logits = logits * (D ** -0.5)
    if mask is not None:
        logits = jnp.where(mask[None, None] if mask.ndim == 2 else mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bhts,bshd->bthd", probs.astype(v.dtype), v, preferred_element_type=jnp.float32
    ).astype(xq.dtype)
    return dense(p["wo"], out.reshape(B, T, H * D))


def _mha_cached(p, cfg, xq, cache: KVCache):
    """Causal self-attention with KV cache (decode)."""
    B, T, _ = xq.shape
    H, D = cfg.num_heads, cfg.head_dim
    q = dense(p["wq"], xq).reshape(B, T, H, D)
    k = dense(p["wk"], xq).reshape(B, T, H, D)
    v = dense(p["wv"], xq).reshape(B, T, H, D)
    idx = cache.index
    k_all = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, idx, 0, 0))
    v_all = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, idx, 0, 0))
    S = cache.k.shape[1]
    mask = jnp.arange(S)[None, :] <= (idx + jnp.arange(T)[:, None])
    logits = jnp.einsum(
        "bthd,bshd->bhts", q, k_all.astype(q.dtype), preferred_element_type=jnp.float32
    ) * (D ** -0.5)
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bhts,bshd->bthd", probs.astype(q.dtype), v_all.astype(q.dtype),
        preferred_element_type=jnp.float32,
    ).astype(xq.dtype)
    y = dense(p["wo"], out.reshape(B, T, H * D))
    return y, KVCache(k=k_all, v=v_all, index=idx + T)


def init_params(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    d = cfg.d_model

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": layernorm_init(d, dtype),
            "attn": _mha_init(k1, cfg, dtype),
            "ln2": layernorm_init(d, dtype),
            "mlp": gelu_mlp_init(k2, d, cfg.d_ff, dtype),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": layernorm_init(d, dtype),
            "self_attn": _mha_init(k1, cfg, dtype),
            "ln2": layernorm_init(d, dtype),
            "cross_attn": _mha_init(k2, cfg, dtype),
            "ln3": layernorm_init(d, dtype),
            "mlp": gelu_mlp_init(k3, d, cfg.d_ff, dtype),
        }

    enc = [enc_layer(jax.random.fold_in(ks[0], i)) for i in range(cfg.num_encoder_layers)]
    dec = [dec_layer(jax.random.fold_in(ks[1], i)) for i in range(cfg.num_layers)]
    stack = lambda ts: jax.tree.map(lambda *xs: jnp.stack(xs), *ts)
    return {
        "enc_pos": truncated_normal(ks[2], (cfg.encoder_seq_len, d), dtype, 0.02),
        "dec_embed": embedding_init(ks[3], cfg.padded_vocab, d, dtype),
        "dec_pos": truncated_normal(ks[4], (4096, d), dtype, 0.02),
        "encoder": stack(enc),
        "decoder": stack(dec),
        "enc_ln": layernorm_init(d, dtype),
        "dec_ln": layernorm_init(d, dtype),
    }


def encode(params, cfg: ModelConfig, frame_embeds: jnp.ndarray):
    """frame_embeds: (B, S_enc, d) — the stubbed conv-frontend output."""
    cd = jnp.dtype(cfg.compute_dtype)
    S = frame_embeds.shape[1]
    h = frame_embeds.astype(cd) + params["enc_pos"][None, :S].astype(cd)
    h = maybe_shard(h, "batch", "seq", None)

    def body(h, p):
        h = h + _mha(p["attn"], cfg, layernorm(p["ln1"], h), layernorm(p["ln1"], h), None)
        h = h + gelu_mlp(p["mlp"], layernorm(p["ln2"], h))
        h = maybe_shard(h, "batch", "seq", None)
        return h, None

    if cfg.scan_layers:
        h, _ = jax.lax.scan(body, h, params["encoder"])
    else:  # cost-probe path: unroll so XLA counts every layer
        for r in range(cfg.num_encoder_layers):
            h, _ = body(h, jax.tree.map(lambda x: x[r], params["encoder"]))
    return layernorm(params["enc_ln"], h)


def decode(
    params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    memory: jnp.ndarray,
    *,
    cache=None,
    position_offset=0,
):
    """Causal decoder over ``tokens`` with cross-attention to ``memory``.

    ``cache``: stacked per-layer KVCache for self-attention (decode mode).
    """
    cd = jnp.dtype(cfg.compute_dtype)
    B, T = tokens.shape
    h = embed(params["dec_embed"], tokens, compute_dtype=cd)
    pos = jax.lax.dynamic_slice_in_dim(params["dec_pos"], position_offset, T, 0)
    h = h + pos[None].astype(cd)
    h = maybe_shard(h, "batch", "seq", None)
    mem = memory.astype(cd)

    if cache is None:
        mask = jnp.tril(jnp.ones((T, T), bool))

        def body(h, p):
            h = h + _mha(p["self_attn"], cfg, layernorm(p["ln1"], h), layernorm(p["ln1"], h), mask)
            h = h + _mha(p["cross_attn"], cfg, layernorm(p["ln2"], h), mem, None)
            h = h + gelu_mlp(p["mlp"], layernorm(p["ln3"], h))
            h = maybe_shard(h, "batch", "seq", None)
            return h, None

        if cfg.scan_layers:
            h, _ = jax.lax.scan(body, h, params["decoder"])
        else:
            for r in range(cfg.num_layers):
                h, _ = body(h, jax.tree.map(lambda x: x[r], params["decoder"]))
        new_cache = None
    else:

        def body(h, xs):
            p, c = xs
            sa, c_new = _mha_cached(p["self_attn"], cfg, layernorm(p["ln1"], h), c)
            h = h + sa
            h = h + _mha(p["cross_attn"], cfg, layernorm(p["ln2"], h), mem, None)
            h = h + gelu_mlp(p["mlp"], layernorm(p["ln3"], h))
            return h, c_new

        if cfg.scan_layers:
            h, new_cache = jax.lax.scan(body, h, (params["decoder"], cache))
        else:
            slices = []
            for r in range(cfg.num_layers):
                h, c_out = body(
                    h,
                    (
                        jax.tree.map(lambda x: x[r], params["decoder"]),
                        jax.tree.map(lambda x: x[r], cache),
                    ),
                )
                slices.append(c_out)
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *slices)

    h = layernorm(params["dec_ln"], h)
    logits = unembed(params["dec_embed"], h)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    logits = maybe_shard(logits, "batch", "seq", "model")
    return logits, new_cache


def init_decoder_cache(cfg: ModelConfig, batch: int, seq: int, dtype, *, index: int = 0):
    caches = [
        kv_cache_init(batch, seq, cfg.num_heads, cfg.head_dim, dtype)
        for _ in range(cfg.num_layers)
    ]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    if index:
        stacked = jax.tree.map(
            lambda l: jnp.full_like(l, index) if l.dtype == jnp.int32 else l, stacked
        )
    return stacked


def loss_fn(params, cfg: ModelConfig, batch):
    """batch: frame_embeds (B, S_enc, d), tokens (B, T), labels (B, T)."""
    memory = encode(params, cfg, batch["frame_embeds"])
    logits, _ = decode(params, cfg, batch["tokens"], memory)
    loss = cross_entropy(logits, batch["labels"], mask=batch.get("loss_mask"))
    return loss, {"ce": loss}


def decode_step(params, cfg: ModelConfig, tokens, memory, cache, *, position):
    logits, new_cache = decode(
        params, cfg, tokens, memory, cache=cache, position_offset=position
    )
    return logits, new_cache
