"""xLSTM blocks — sLSTM (scalar memory) + mLSTM (matrix memory).

Follows arXiv:2405.04517.  mLSTM has a parallel (attention-like, with the
stabilized exponential-gating decay matrix D) form for train/prefill and an
O(1)-state recurrent form for decode; sLSTM is inherently sequential
(``lax.scan`` over time; per-head block-diagonal recurrence) and carries a
4-tuple state.  Both are sub-quadratic in memory at decode time, which is
why xlstm-125m runs the ``long_500k`` shape.

Simplifications vs. the reference implementation (noted in DESIGN.md): the
small causal conv preceding q/k in the mLSTM block is omitted; projection
factors follow the paper (2.0 mLSTM, 4/3 sLSTM).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.cache import MLSTMCache, SLSTMCache
from repro.models.config import ModelConfig
from repro.models.layers import dense, dense_init, rmsnorm, rmsnorm_init


# ----------------------------------------------------------------------------
# mLSTM
# ----------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    H = cfg.num_heads
    di = int(cfg.xlstm.proj_factor_mlstm * d)
    di = (di // H) * H  # divisible by heads
    ks = jax.random.split(key, 8)
    return {
        "up_proj": dense_init(ks[0], d, 2 * di, dtype=dtype),
        "wq": dense_init(ks[1], di, di, dtype=dtype),
        "wk": dense_init(ks[2], di, di, dtype=dtype),
        "wv": dense_init(ks[3], di, di, dtype=dtype),
        "w_i": dense_init(ks[4], di, H, bias=True, dtype=dtype),
        "w_f": dense_init(ks[5], di, H, bias=True, dtype=dtype),
        "mh_norm": rmsnorm_init(di, dtype),
        "down_proj": dense_init(ks[6], di, d, dtype=dtype),
    }


def _mlstm_chunk(state: MLSTMCache, inputs):
    """One chunk of the chunkwise-parallel mLSTM (the memory-lean train/
    prefill form — the full (T, T) decay matrix would be O(B·T²·H)).

    Derivation (stabilized): with in-chunk cumulative log-forget
    ``b_t = Σ_{r≤t} log σ(f_r)`` and running stabilizer
    ``g_t = max(m_0, max_{s≤t}(i_s − b_s))`` (so ``m_t = b_t + g_t``):

        h_t ∝ Σ_{s≤t} exp(i_s − b_s − g_t)·(q̃_t·k_s)·v_s
              + exp(m_0 − g_t)·(q̃_t · C_0)

    with the xLSTM max(|den|, exp(−m_t)) normalizer; the end-of-chunk state
    uses the same weights at t = L.  Memory: O(B·L²·H) per chunk.
    """
    q, k, v, i_pre, f_pre = inputs  # (B, L, H, Dh) / (B, L, H)
    B, L, H, Dh = q.shape
    C0, n0, m0 = state.C, state.n, state.m  # (B,H,Dk,Dv), (B,H,Dk), (B,H)

    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))  # (B,L,H)
    logi = i_pre.astype(jnp.float32)
    b = jnp.cumsum(logf, axis=1)  # (B,L,H)
    a = jnp.maximum(jax.lax.cummax(logi - b, axis=1), -1e30)  # (B,L,H)
    g = jnp.maximum(m0[:, None], a)  # (B,L,H)
    m = b + g  # (B,L,H) = m_t

    qf = q.astype(jnp.float32) * (Dh ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    # intra-chunk decay weights  D_ts = i_s − b_s − g_t   (s ≤ t)
    ib = logi - b  # (B,L,H) at s
    Dmat = ib[:, None, :, :] - g[:, :, None, :]  # (B,T,S,H)
    tri = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])[None, :, :, None]
    W = jnp.where(tri, jnp.exp(Dmat), 0.0)  # (B,T,S,H)

    S = jnp.einsum("bthd,bshd->btsh", qf, kf)  # scores
    num_intra = jnp.einsum("btsh,bshd->bthd", W * S, vf)
    den_intra = jnp.sum(W * S, axis=2)  # (B,T,H)

    # inter-chunk contribution from carried state
    scale0 = jnp.exp(m0[:, None] - g)  # (B,L,H)
    num_inter = jnp.einsum("bthd,bhdv->bthv", qf, C0) * scale0[..., None]
    den_inter = jnp.einsum("bthd,bhd->bth", qf, n0) * scale0

    num = num_intra + num_inter
    den = den_intra + den_inter
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]

    # end-of-chunk state (t = L)
    gL = g[:, -1]  # (B,H)
    wL = jnp.exp(ib - gL[:, None])  # (B,L,H)
    C_new = jnp.exp(m0 - gL)[..., None, None] * C0 + jnp.einsum(
        "blh,blhk,blhv->bhkv", wL, kf, vf
    )
    n_new = jnp.exp(m0 - gL)[..., None] * n0 + jnp.einsum("blh,blhk->bhk", wL, kf)
    m_new = b[:, -1] + gL
    return MLSTMCache(C=C_new, n=n_new, m=m_new), h


def _mlstm_parallel(q, k, v, i_pre, f_pre, *, chunk: int = 256):
    """Chunkwise-parallel mLSTM over the full sequence.
    q,k,v: (B,T,H,Dh); i,f: (B,T,H).  Scans chunks of ``chunk`` tokens."""
    B, T, H, Dh = q.shape
    L = min(chunk, T)
    pad = (-T) % L
    if pad:
        zpad = lambda x: jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))
        q, k, v = zpad(q), zpad(k), zpad(v)
        i_pre = zpad(i_pre)
        # padded steps must not pollute the state: forget ≈ 1, input ≈ -inf
        f_pre = jnp.concatenate(
            [f_pre, jnp.full((B, pad, H), 30.0, f_pre.dtype)], axis=1
        )
        i_pre = i_pre.at[:, T:].set(-1e30)
    nch = (T + pad) // L

    def to_chunks(x):
        return x.reshape(B, nch, L, *x.shape[2:]).swapaxes(0, 1)

    state0 = MLSTMCache(
        C=jnp.zeros((B, H, Dh, Dh), jnp.float32),
        n=jnp.zeros((B, H, Dh), jnp.float32),
        m=jnp.full((B, H), -1e30, jnp.float32),
    )
    if nch == 1:
        _, h = _mlstm_chunk(state0, (q, k, v, i_pre, f_pre))
        h = h[:, :T]
    else:
        xs = tuple(map(to_chunks, (q, k, v, i_pre, f_pre)))
        _, hs = jax.lax.scan(_mlstm_chunk, state0, xs)
        h = hs.swapaxes(0, 1).reshape(B, nch * L, H, Dh)[:, :T]
    return h.astype(q.dtype)


def _mlstm_step(cache: MLSTMCache, q, k, v, i_pre, f_pre):
    """Recurrent mLSTM step.  q,k,v: (B,H,Dh); i,f: (B,H)."""
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    logi = i_pre.astype(jnp.float32)
    m_new = jnp.maximum(logf + cache.m, logi)  # (B,H)
    fw = jnp.exp(logf + cache.m - m_new)[..., None]
    iw = jnp.exp(logi - m_new)[..., None]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C = cache.C * fw[..., None] + iw[..., None] * kf[..., None] * vf[..., None, :]
    n = cache.n * fw + iw * kf
    qf = q.astype(jnp.float32) * (q.shape[-1] ** -0.5)
    num = jnp.einsum("bhkv,bhk->bhv", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)), jnp.exp(-m_new))
    h = num / den[..., None]
    return MLSTMCache(C=C, n=n, m=m_new), h.astype(q.dtype)


def mlstm_apply(p, cfg: ModelConfig, x, *, cache: MLSTMCache | None = None, **_):
    B, T, d = x.shape
    H = cfg.num_heads
    up = dense(p["up_proj"], x)
    xi, z = jnp.split(up, 2, axis=-1)  # (B,T,di) each
    di = xi.shape[-1]
    Dh = di // H
    q = dense(p["wq"], xi).reshape(B, T, H, Dh)
    k = dense(p["wk"], xi).reshape(B, T, H, Dh)
    v = dense(p["wv"], xi).reshape(B, T, H, Dh)
    i_pre = dense(p["w_i"], xi)  # (B,T,H)
    f_pre = dense(p["w_f"], xi)

    if cache is None:
        chunk = T if cfg.unroll_time_scans else 256
        h = _mlstm_parallel(q, k, v, i_pre, f_pre, chunk=chunk)  # (B,T,H,Dh)
        new_cache = None
    else:
        assert T == 1, "recurrent mLSTM path is for decode (T==1)"
        new_cache, h1 = _mlstm_step(
            cache, q[:, 0], k[:, 0], v[:, 0], i_pre[:, 0], f_pre[:, 0]
        )
        h = h1[:, None]
    h = h.reshape(B, T, di)
    h = rmsnorm(p["mh_norm"], h, eps=cfg.rms_eps)
    out = dense(p["down_proj"], h * jax.nn.silu(z))
    return out, new_cache


# ----------------------------------------------------------------------------
# sLSTM
# ----------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    ks = jax.random.split(key, 10)
    df = int(cfg.xlstm.proj_factor_slstm * d)

    def rinit(k):  # block-diagonal recurrent weights, stored (H, dh, dh)
        return (1.0 / dh) ** 0.5 * jax.random.normal(k, (H, dh, dh)).astype(dtype)

    return {
        "w_z": dense_init(ks[0], d, d, bias=True, dtype=dtype),
        "w_i": dense_init(ks[1], d, d, bias=True, dtype=dtype),
        "w_f": dense_init(ks[2], d, d, bias=True, dtype=dtype),
        "w_o": dense_init(ks[3], d, d, bias=True, dtype=dtype),
        "r_z": rinit(ks[4]),
        "r_i": rinit(ks[5]),
        "r_f": rinit(ks[6]),
        "r_o": rinit(ks[7]),
        "group_norm": rmsnorm_init(d, dtype),
        "ffn_up": dense_init(ks[8], d, 2 * df, dtype=dtype),
        "ffn_down": dense_init(ks[9], df, d, dtype=dtype),
    }


def _block_recur(r, h, H, dh):
    """Block-diagonal recurrence: h (B, d) → (B, d)."""
    B = h.shape[0]
    hb = h.reshape(B, H, dh)
    return jnp.einsum("bhk,hkd->bhd", hb, r).reshape(B, H * dh)


def _slstm_step(p, cfg, state: SLSTMCache, zifo):
    """One sLSTM time step; zifo: tuple of (B, d) pre-activations (input part)."""
    H = cfg.num_heads
    d = state.h.shape[-1]
    dh = d // H
    hz, hi, hf, ho = (
        _block_recur(p["r_z"].astype(jnp.float32), state.h, H, dh),
        _block_recur(p["r_i"].astype(jnp.float32), state.h, H, dh),
        _block_recur(p["r_f"].astype(jnp.float32), state.h, H, dh),
        _block_recur(p["r_o"].astype(jnp.float32), state.h, H, dh),
    )
    xz, xi, xf, xo = zifo
    z = jnp.tanh(xz + hz)
    logi = xi + hi  # exponential input gate (log-space)
    logf = jax.nn.log_sigmoid(xf + hf)
    o = jax.nn.sigmoid(xo + ho)
    m_new = jnp.maximum(logf + state.m, logi)
    fw = jnp.exp(logf + state.m - m_new)
    iw = jnp.exp(logi - m_new)
    c = fw * state.c + iw * z
    n = fw * state.n + iw
    h = o * c / jnp.maximum(n, 1e-6)
    return SLSTMCache(c=c, n=n, h=h, m=m_new)


def slstm_apply(p, cfg: ModelConfig, x, *, cache: SLSTMCache | None = None, **_):
    B, T, d = x.shape
    cd = x.dtype
    xz = dense(p["w_z"], x).astype(jnp.float32)
    xi = dense(p["w_i"], x).astype(jnp.float32)
    xf = dense(p["w_f"], x).astype(jnp.float32)
    xo = dense(p["w_o"], x).astype(jnp.float32)

    state0 = (
        cache
        if cache is not None
        else SLSTMCache(
            c=jnp.zeros((B, d), jnp.float32),
            n=jnp.zeros((B, d), jnp.float32),
            h=jnp.zeros((B, d), jnp.float32),
            m=jnp.full((B, d), -1e30, jnp.float32),
        )
    )

    def step(state, zifo):
        new = _slstm_step(p, cfg, state, zifo)
        return new, new.h

    state_fin, hs = jax.lax.scan(
        step,
        state0,
        (
            xz.swapaxes(0, 1),
            xi.swapaxes(0, 1),
            xf.swapaxes(0, 1),
            xo.swapaxes(0, 1),
        ),
    )
    h = hs.swapaxes(0, 1).astype(cd)  # (B, T, d)
    h = rmsnorm(p["group_norm"], h, eps=cfg.rms_eps)
    # post-up/down GLU FFN (paper's proj factor 4/3)
    up = dense(p["ffn_up"], h)
    a, b = jnp.split(up, 2, axis=-1)
    out = dense(p["ffn_down"], jax.nn.gelu(a) * b)
    new_cache = state_fin if cache is not None else None
    return out, new_cache
