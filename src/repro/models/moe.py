"""Mixture-of-Experts FFN with top-k routing, shared experts, aux loss.

Capacity-based scatter dispatch (Switch-style) chosen for TPU SPMD:

* tokens are ranked within their expert by a **sort-based** position
  computation (O(M log M) memory-lean; avoids the (M, E) one-hot cumsum
  which at deepseek-v3 scale would materialize ~0.5 GB per device);
* tokens beyond ``capacity = cf · M · k / E`` are dropped (gate contribution
  zero) — standard capacity truncation;
* the (E, C, d) expert buffer is sharded over the ``model`` axis (expert
  parallelism): the scatter/gather between token-sharded and expert-sharded
  layouts is exactly the MoE all-to-all the roofline analysis tracks.

Aux load-balance loss (Switch/DeepSeek form): ``E · Σ_e f_e · P_e`` with
``f_e`` the dispatch fraction and ``P_e`` the mean router probability.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, swiglu, swiglu_init, truncated_normal


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.moe
    kr, ke, ks = jax.random.split(key, 3)
    d, f, E = cfg.d_model, m.d_ff_expert, m.num_experts
    k1, k2, k3 = jax.random.split(ke, 3)
    std_in, std_out = (1.0 / d) ** 0.5, (1.0 / f) ** 0.5
    p = {
        "router": dense_init(kr, d, E, dtype=jnp.float32),  # router kept fp32
        "experts": {
            "w_gate": truncated_normal(k1, (E, d, f), dtype, std_in),
            "w_up": truncated_normal(k2, (E, d, f), dtype, std_in),
            "w_down": truncated_normal(k3, (E, f, d), dtype, std_out),
        },
    }
    if m.num_shared_experts > 0:
        p["shared"] = swiglu_init(
            ks, d, m.d_ff_shared * m.num_shared_experts, dtype=dtype
        )
    return p


def _positions_in_expert(ids_f: jnp.ndarray, num_experts: int) -> jnp.ndarray:
    """Rank of each dispatch entry within its expert, via stable sort —
    O(M) memory instead of the (M, E) cumsum."""
    M = ids_f.shape[0]
    order = jnp.argsort(ids_f, stable=True)
    sorted_ids = ids_f[order]
    idx = jnp.arange(M, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]]
    )
    run_start = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, idx, 0))
    rank_sorted = idx - run_start
    return jnp.zeros((M,), jnp.int32).at[order].set(rank_sorted)


def moe_apply(p, cfg: ModelConfig, x: jnp.ndarray, *, compute_dtype=None):
    """Returns (y, aux_loss).  x: (B, T, d).

    Dispatch is **grouped per batch row** (group = sequence): positions and
    capacity are computed within each row, so every intermediate stays
    sharded (batch → data axis, experts → model axis) and the only
    resharding is the (B, E, C, d) expert buffer — the MoE all-to-all.
    A globally-flattened dispatch would force SPMD to replicate the (N·k, d)
    gather (~68 GB/device at olmoe train scale).  Per-row capacity is the
    standard group-limited variant (slightly stricter than global capacity).
    """
    m = cfg.moe
    B, T, d = x.shape
    E, k = m.num_experts, m.top_k
    cd = compute_dtype or x.dtype
    C = max(1, int(m.capacity_factor * T * k / E))

    # --- routing (fp32)
    logits = x.astype(jnp.float32) @ p["router"]["kernel"]  # (B, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (B, T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # --- aux load-balance loss (global over the batch)
    f_e = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=2),
        axis=(0, 1),
    ) / k
    P_e = jnp.mean(probs, axis=(0, 1))
    aux = m.aux_loss_coef * E * jnp.sum(f_e * P_e)

    M = T * k
    tok_f = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)  # (M,)

    def dispatch_row(xr, ids, gates):
        """xr (T, d); ids/gates (T, k) → buffer (E, C, d) + combine info."""
        ids_f = ids.reshape(M)
        gate_f = gates.reshape(M)
        pos = _positions_in_expert(ids_f, E)
        keep = pos < C
        dest = jnp.where(keep, ids_f * C + pos, E * C)  # overflow → trash
        buf = jnp.zeros((E * C + 1, d), cd).at[dest].set(xr[tok_f].astype(cd))
        return buf[: E * C].reshape(E, C, d), dest, keep, gate_f

    xe, dest, keep, gate_f = jax.vmap(dispatch_row)(x, expert_ids, gate_vals)
    # xe: (B, E, C, d) — resharding to (data, model, ·, ·) is the all-to-all.
    # Pin the layout explicitly: without the constraint the SPMD partitioner
    # has been observed to replicate the buffer and re-slice it (an
    # all-gather of the whole dispatch buffer) instead of emitting the
    # token-sized all-to-all — see EXPERIMENTS.md §Perf hillclimb A.
    from repro.sharding.rules import maybe_shard

    xe = maybe_shard(xe, "batch", "model", None, None)

    # --- expert FFN (SwiGLU), batched over experts
    w = p["experts"]
    g = jnp.einsum("becd,edf->becf", xe, w["w_gate"].astype(cd))
    u = jnp.einsum("becd,edf->becf", xe, w["w_up"].astype(cd))
    h = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u, w["w_down"].astype(cd))
    h = maybe_shard(h, "batch", "model", None, None)

    def combine_row(hr, dest_r, keep_r, gate_r):
        hf = hr.reshape(E * C, d)
        ent = jnp.where(
            keep_r[:, None], hf[jnp.minimum(dest_r, E * C - 1)], 0.0
        ) * gate_r[:, None].astype(cd)
        return jnp.zeros((T, d), cd).at[tok_f].add(ent)

    y = jax.vmap(combine_row)(h, dest, keep, gate_f)

    if "shared" in p:
        y = y + swiglu(p["shared"], x, compute_dtype=cd)

    return y.astype(x.dtype), aux
