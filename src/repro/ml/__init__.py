"""The surveyed distributed-ML algorithm families (paper §3–§4)."""

from repro.ml import clustering, gp, graphical, kwindows, linear, svm

__all__ = ["clustering", "gp", "graphical", "kwindows", "linear", "svm"]
