"""Distributed Gaussian Processes (paper §3.3).

Exact GP regression plus the full family of distributed expert-combination
models the paper surveys, with the paper's exact formulas:

* ``poe``   — Product-of-Experts: (σ*)⁻² = Σ_k (σ_k*)⁻²;
* ``gpoe``  — generalized PoE [13]: (σ*)⁻² = Σ_k β_k (σ_k*)⁻², falls back to
              the prior outside the data when Σβ_k = 1 ("in a central server
              model coordination to ensure Σβ_k = 1 is easy to accomplish");
* ``bcm``   — Bayesian Committee Machine [67]:
              (σ*)⁻² = Σ_k (σ_k*)⁻² + (1 − K)·σ₀⁻²;
* ``gbcm``  — generalized/robust BCM [17]:
              (σ*)⁻² = Σ_k β_k (σ_k*)⁻² + (1 − Σ_k β_k)·σ₀⁻²;
* ``moe_map`` — the [46] MoE with MAP proximity assignment
              ẑ_n = argmin_p (x_n − m_p)ᵀ V⁻¹ (x_n − m_p).

Hyperparameters are trained by maximizing the exact (or PoE-factorized,
i.e. sum of per-expert) log marginal likelihood with gradients — the
factorized objective "transforms the objective function used for training
in K separable [terms]" (paper §3.3), which is the distributed-training
step: each node contributes its local term and one Allreduce sums them.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------------------
# Kernel + exact GP
# ----------------------------------------------------------------------------

class GPHypers(NamedTuple):
    log_lengthscale: jnp.ndarray
    log_signal: jnp.ndarray
    log_noise: jnp.ndarray


def default_hypers() -> GPHypers:
    return GPHypers(
        log_lengthscale=jnp.asarray(0.0),
        log_signal=jnp.asarray(0.0),
        log_noise=jnp.asarray(-2.0),
    )


def rbf(hyp: GPHypers, A: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    ell = jnp.exp(hyp.log_lengthscale)
    sf2 = jnp.exp(2.0 * hyp.log_signal)
    d2 = (
        jnp.sum(A * A, axis=1)[:, None]
        - 2.0 * A @ B.T
        + jnp.sum(B * B, axis=1)[None, :]
    )
    return sf2 * jnp.exp(-0.5 * jnp.maximum(d2, 0.0) / (ell * ell))


def gp_posterior(hyp: GPHypers, X, y, Xq):
    """Exact GP posterior mean/variance at query points (zero prior mean)."""
    sn2 = jnp.exp(2.0 * hyp.log_noise)
    Kxx = rbf(hyp, X, X) + sn2 * jnp.eye(X.shape[0])
    Lc = jnp.linalg.cholesky(Kxx)
    alpha = jax.scipy.linalg.cho_solve((Lc, True), y)
    Kqx = rbf(hyp, Xq, X)
    mu = Kqx @ alpha
    v = jax.scipy.linalg.solve_triangular(Lc, Kqx.T, lower=True)
    var = jnp.diag(rbf(hyp, Xq, Xq)) - jnp.sum(v * v, axis=0)
    return mu, jnp.maximum(var, 1e-10)


def log_marginal_likelihood(hyp: GPHypers, X, y):
    sn2 = jnp.exp(2.0 * hyp.log_noise)
    N = X.shape[0]
    Kxx = rbf(hyp, X, X) + sn2 * jnp.eye(N)
    Lc = jnp.linalg.cholesky(Kxx)
    alpha = jax.scipy.linalg.cho_solve((Lc, True), y)
    return (
        -0.5 * y @ alpha
        - jnp.sum(jnp.log(jnp.diag(Lc)))
        - 0.5 * N * jnp.log(2.0 * jnp.pi)
    )


def _adagrad_ascent(neg_obj, hyp, steps, lr):
    """Adagrad steps on a (normalized) negative objective — the paper's
    cited [19] adaptive procedure; robust to the LL's scale."""
    grad = jax.grad(neg_obj)
    acc0 = jax.tree.map(jnp.zeros_like, hyp)

    def step(carry, _):
        h, acc = carry
        g = grad(h)
        acc = jax.tree.map(lambda a, gi: a + gi * gi, acc, g)
        h = jax.tree.map(
            lambda p, gi, a: p - lr * gi / (jnp.sqrt(a) + 1e-8), h, g, acc
        )
        return (h, acc), None

    (hyp, _), _ = jax.lax.scan(step, (hyp, acc0), None, length=steps)
    return hyp


def fit_hypers(
    X, y, *, steps: int = 100, lr: float = 0.1, hyp0: GPHypers | None = None
) -> GPHypers:
    """Adaptive gradient ascent on the mean log marginal likelihood."""
    hyp = default_hypers() if hyp0 is None else hyp0
    N = X.shape[0]
    return _adagrad_ascent(
        lambda h: -log_marginal_likelihood(h, X, y) / N, hyp, steps, lr
    )


def fit_hypers_distributed(
    Xs, ys, *, steps: int = 100, lr: float = 0.1, hyp0: GPHypers | None = None,
    ledger=None,
) -> GPHypers:
    """PoE-factorized training: maximize Σ_k log p(y_k | X_k, θ).

    Each node computes the gradient of its local marginal-likelihood term;
    one Allreduce (here: the vmap+sum) aggregates — K separable objectives,
    exactly the paper's factorized-likelihood training.  Pass a
    ``CommLedger`` as ``ledger`` to account the per-step hyper-gradient
    Allreduce (one push + pull of the 3-scalar hyper vector per node).
    """
    hyp = default_hypers() if hyp0 is None else hyp0
    N = Xs.shape[0] * Xs.shape[1]

    def neg_total(h):
        lls = jax.vmap(lambda X, y: log_marginal_likelihood(h, X, y))(Xs, ys)
        return -jnp.sum(lls) / N

    hyp = _adagrad_ascent(neg_total, hyp, steps, lr)
    if ledger is not None:
        for _ in range(steps):
            ledger.record_allreduce(hyp, Xs.shape[0], tag="gp-hyper-grad")
    return hyp


# ----------------------------------------------------------------------------
# Expert-combination rules (the paper's §3.3 formulas, verbatim)
# ----------------------------------------------------------------------------

class ExpertPreds(NamedTuple):
    mu: jnp.ndarray  # (K, Q) per-expert posterior means
    var: jnp.ndarray  # (K, Q) per-expert posterior variances


def expert_predictions(hyp: GPHypers, Xs, ys, Xq) -> ExpertPreds:
    mu, var = jax.vmap(lambda X, y: gp_posterior(hyp, X, y, Xq))(Xs, ys)
    return ExpertPreds(mu=mu, var=var)


def poe(preds: ExpertPreds):
    prec = jnp.sum(1.0 / preds.var, axis=0)
    var = 1.0 / prec
    mu = var * jnp.sum(preds.mu / preds.var, axis=0)
    return mu, var


def gpoe(preds: ExpertPreds, beta: jnp.ndarray | None = None):
    K = preds.mu.shape[0]
    if beta is None:
        beta = jnp.full((K,), 1.0 / K)  # Σβ = 1 → falls back to the prior
    prec = jnp.sum(beta[:, None] / preds.var, axis=0)
    var = 1.0 / prec
    mu = var * jnp.sum(beta[:, None] * preds.mu / preds.var, axis=0)
    return mu, var


def bcm(preds: ExpertPreds, prior_var: jnp.ndarray):
    K = preds.mu.shape[0]
    prec = jnp.sum(1.0 / preds.var, axis=0) + (1.0 - K) / prior_var
    var = 1.0 / prec
    mu = var * jnp.sum(preds.mu / preds.var, axis=0)
    return mu, var


def gbcm(preds: ExpertPreds, prior_var: jnp.ndarray, beta: jnp.ndarray | None = None):
    """Robust BCM; default β_k = ½(log σ₀² − log σ_k²) (differential entropy)."""
    if beta is None:
        beta_kq = 0.5 * (jnp.log(prior_var)[None, :] - jnp.log(preds.var))
    else:
        beta_kq = jnp.broadcast_to(beta[:, None], preds.mu.shape)
    prec = jnp.sum(beta_kq / preds.var, axis=0) + (
        1.0 - jnp.sum(beta_kq, axis=0)
    ) / prior_var
    prec = jnp.maximum(prec, 1e-10)
    var = 1.0 / prec
    mu = var * jnp.sum(beta_kq * preds.mu / preds.var, axis=0)
    return mu, var


def prior_variance(hyp: GPHypers, Xq) -> jnp.ndarray:
    return jnp.diag(rbf(hyp, Xq, Xq))


# ----------------------------------------------------------------------------
# Sparse GP (Titsias [66]) + distributed aggregation ([23])
# ----------------------------------------------------------------------------

class SGPRStats(NamedTuple):
    """Per-shard sufficient statistics for the variational sparse GP.

    The collapsed-ELBO posterior depends on the data only through
    A = Kmn Knm, b = Kmn y and t = Σ_n k(x_n,x_n) — all ADDITIVE over data
    shards, which is exactly why [23] can compute them "in an
    embarrassingly parallel model on each node" and aggregate at a central
    node with one Allreduce.
    """

    A: jnp.ndarray  # (M, M)
    b: jnp.ndarray  # (M,)
    t: jnp.ndarray  # scalar Σ k(x,x)
    n: jnp.ndarray  # scalar count


def sgpr_local_stats(hyp: GPHypers, Z: jnp.ndarray, X: jnp.ndarray, y: jnp.ndarray) -> SGPRStats:
    Kmn = rbf(hyp, Z, X)  # (M, Nk)
    return SGPRStats(
        A=Kmn @ Kmn.T,
        b=Kmn @ y,
        t=jnp.sum(jax.vmap(lambda x: rbf(hyp, x[None], x[None])[0, 0])(X)),
        n=jnp.asarray(float(X.shape[0])),
    )


def sgpr_aggregate(stats_stacked: SGPRStats) -> SGPRStats:
    """The central-server Allreduce over per-node statistics."""
    return SGPRStats(
        A=jnp.sum(stats_stacked.A, axis=0),
        b=jnp.sum(stats_stacked.b, axis=0),
        t=jnp.sum(stats_stacked.t),
        n=jnp.sum(stats_stacked.n),
    )


def sgpr_posterior(hyp: GPHypers, Z: jnp.ndarray, stats: SGPRStats, Xq: jnp.ndarray):
    """Titsias posterior from aggregated statistics.

    q(u) = N(m_u, S);  S = Kmm Σ⁻¹ Kmm,  m_u = σ⁻² Kmm Σ⁻¹ b,
    Σ = Kmm + σ⁻² A.  Prediction: μ* = K*m Kmm⁻¹ m_u (computed stably via
    Σ solves — no explicit Kmm⁻¹).
    """
    M = Z.shape[0]
    sn2 = jnp.exp(2.0 * hyp.log_noise)
    Kmm = rbf(hyp, Z, Z) + 1e-6 * jnp.eye(M)
    Sigma = Kmm + stats.A / sn2
    # μ* = σ⁻² K*m Σ⁻¹ b
    Kqm = rbf(hyp, Xq, Z)
    alpha = jnp.linalg.solve(Sigma, stats.b) / sn2
    mu = Kqm @ alpha
    # var* = K** − K*m (Kmm⁻¹ − Σ⁻¹) Km*
    v1 = jnp.linalg.solve(Kmm, Kqm.T)
    v2 = jnp.linalg.solve(Sigma, Kqm.T)
    var = (
        jnp.diag(rbf(hyp, Xq, Xq))
        - jnp.sum(Kqm.T * v1, axis=0)
        + jnp.sum(Kqm.T * v2, axis=0)
    )
    return mu, jnp.maximum(var, 1e-10)


def sgpr_elbo(hyp: GPHypers, Z: jnp.ndarray, stats: SGPRStats):
    """Collapsed Titsias ELBO from aggregated statistics (trainable in the
    distributed setting: nodes recompute local stats per hyper step, one
    Allreduce, server evaluates/differentiates this scalar)."""
    M = Z.shape[0]
    N = stats.n
    sn2 = jnp.exp(2.0 * hyp.log_noise)
    Kmm = rbf(hyp, Z, Z) + 1e-6 * jnp.eye(M)
    Sigma = Kmm + stats.A / sn2
    Lk = jnp.linalg.cholesky(Kmm)
    Ls = jnp.linalg.cholesky(Sigma)
    # log|Qnn + σ²I| = log|Σ| − log|Kmm| + N log σ²
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diag(Ls))) - 2.0 * jnp.sum(
        jnp.log(jnp.diag(Lk))
    ) + N * jnp.log(sn2)
    # yᵀ(Qnn+σ²I)⁻¹y = (yᵀy − σ⁻² bᵀΣ⁻¹b)/σ²  — yᵀy enters via stats.t? no:
    # yᵀy must be carried too; we fold it into t2 (see caller) — here we
    # accept quad = yᵀy precomputed in stats.t slot for the ELBO variant.
    quad = (stats.t - (stats.b @ jnp.linalg.solve(Sigma, stats.b)) / sn2) / sn2
    # trace correction: σ⁻²(Σk(x,x) − tr(Kmm⁻¹ A)) — uses true Σk(x,x);
    # callers wanting the exact ELBO should pass both t=Σk(x,x) and yᵀy;
    # for hyper-fitting the quad form with t=yᵀy is the dominant term.
    return -0.5 * (logdet + quad + N * jnp.log(2.0 * jnp.pi))


def distributed_sgpr(
    hyp: GPHypers,
    Z: jnp.ndarray,
    Xs: jnp.ndarray,  # (K, Nk, d) shards
    ys: jnp.ndarray,
    Xq: jnp.ndarray,
    *,
    ledger=None,
):
    """[23]'s construction end-to-end: local stats per node (vmap = the K
    workers), central aggregation, posterior from the aggregate.  Returns
    (mu, var, per-node-stats-bytes), with the byte cost measured by the
    ``repro.api`` Wire layer ((M²+M+2)·4 — independent of N, the paper's
    point).  Pass a ``CommLedger`` as ``ledger`` to record the K stat
    pushes."""
    from repro.api.wire import DenseWire

    stats = jax.vmap(lambda X, y: sgpr_local_stats(hyp, Z, X, y))(Xs, ys)
    agg = sgpr_aggregate(stats)
    mu, var = sgpr_posterior(hyp, Z, agg, Xq)
    per_node = jax.tree.map(lambda s: s[0], stats)  # one SGPRStats push
    wire = DenseWire().measure(per_node)
    if ledger is not None:
        for k in range(Xs.shape[0]):
            ledger.record_push(per_node, tag=f"sgpr-stats-node{k}")
    return mu, var, wire


# ----------------------------------------------------------------------------
# MoE with MAP proximity assignment ([46])
# ----------------------------------------------------------------------------

def moe_map_assign(X: jnp.ndarray, inducing_means: jnp.ndarray, V_diag: jnp.ndarray):
    """ẑ_n = argmin_p (x_n − m_p)ᵀ V⁻¹ (x_n − m_p) — fast expert allocation."""
    diff = X[:, None, :] - inducing_means[None, :, :]  # (N, P, d)
    d2 = jnp.sum(diff * diff / V_diag[None, None, :], axis=-1)
    return jnp.argmin(d2, axis=1)


def moe_predict(hyp: GPHypers, X, y, Xq, inducing_means, V_diag):
    """Hard-assignment MoE: each query point is answered by its MAP expert."""
    P = inducing_means.shape[0]
    z_train = moe_map_assign(X, inducing_means, V_diag)
    z_query = moe_map_assign(Xq, inducing_means, V_diag)

    # fixed-shape per-expert masked GP (weights zero out other experts'
    # points via a huge noise term on masked-out rows)
    def expert(p):
        m = (z_train == p).astype(X.dtype)
        sn2 = jnp.exp(2.0 * hyp.log_noise)
        big = 1e6
        noise = sn2 + big * (1.0 - m)
        Kxx = rbf(hyp, X, X) + jnp.diag(noise)
        Lc = jnp.linalg.cholesky(Kxx)
        alpha = jax.scipy.linalg.cho_solve((Lc, True), y * m)
        Kqx = rbf(hyp, Xq, X)
        mu = Kqx @ alpha
        v = jax.scipy.linalg.solve_triangular(Lc, Kqx.T, lower=True)
        var = jnp.diag(rbf(hyp, Xq, Xq)) - jnp.sum(v * v, axis=0)
        return mu, jnp.maximum(var, 1e-10)

    mus, vars_ = jax.vmap(expert)(jnp.arange(P))
    sel = jax.nn.one_hot(z_query, P).T  # (P, Q)
    return jnp.sum(mus * sel, axis=0), jnp.sum(vars_ * sel, axis=0)
