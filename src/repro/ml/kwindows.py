"""K-windows clustering — the paper's §4.2 exhaustive treatment.

The paper translates the empirical k-windows algorithm [69] into the
ℓ∞-constrained k-means

    min_{c_k} Σ_i Σ_k  1{‖x_i − c_k‖_{ℓ∞^w} < r} · ‖x_i − c_k‖²₂

"a K-means algorithm where the E-step is skipped and simply replaced with
the cluster assignments u_{i,k} = 1{‖x_i − c_k‖_∞ < r} and the M-step
remaining the same", followed by:

* **Phase 2 (enlargement)** — per cluster k and coordinate d the window
  weight w_d is relaxed (window grows) while the capture-ratio gain
  card(new)/card(old) ≥ θ_e;
* **Phase 3 (merging)** — clusters are merged when the overlap count ratio
  card(x in W_i ∩ W_j)/min card exceeds θ_m (paper: ratio of captured
  counts), seeded from pairs with dist(c_i, c_j) < 2·max window radius.

Windows are boxes: center ``c`` (K, d) + halfwidths ``h`` (K, d); the
weighted ℓ∞ norm of the paper is ‖x−c‖_{ℓ∞^w} = max_d |x_d−c_d|/h_d (so the
window is the unit ball).  A point may satisfy several window indicators;
ties go to the nearest center in ℓ2 (the paper notes unassigned-overlap
handling is an open gap in [69] — we make the standard choice and say so).

``distributed_kwindows`` implements [60]'s naive variant: nodes run local
k-windows and the server merges ALL overlapping windows regardless of
overlap counts — the paper's observed failure mode (over-merging of close
clusters) is reproduced in ``benchmarks/bench_clustering.py``.
"""

from __future__ import annotations

import warnings
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.api import executor as _exec
from repro.api.strategy import Strategy


class KWindows(NamedTuple):
    centers: jnp.ndarray  # (K, d)
    halfwidths: jnp.ndarray  # (K, d)
    alive: jnp.ndarray  # (K,) 1.0 = active cluster
    counts: jnp.ndarray  # (K,) points captured


def window_membership(X: jnp.ndarray, win: KWindows) -> jnp.ndarray:
    """(N, K) indicator u_{i,k} = 1{‖x_i − c_k‖_{ℓ∞^w} < 1} (and k alive)."""
    z = jnp.abs(X[:, None, :] - win.centers[None, :, :]) / jnp.maximum(
        win.halfwidths[None, :, :], 1e-12
    )
    inside = jnp.max(z, axis=-1) < 1.0
    return inside & (win.alive[None, :] > 0)


def assign_points(X: jnp.ndarray, win: KWindows) -> jnp.ndarray:
    """Resolve overlapping membership by nearest center (ℓ2); -1 = uncaptured."""
    member = window_membership(X, win)
    d2 = jnp.sum((X[:, None, :] - win.centers[None, :, :]) ** 2, axis=-1)
    d2 = jnp.where(member, d2, jnp.inf)
    a = jnp.argmin(d2, axis=1)
    return jnp.where(jnp.any(member, axis=1), a, -1)


def _masked_mean(X, mask, fallback):
    cnt = jnp.sum(mask, axis=0)  # (K,)
    s = mask.T @ X  # (K, d)
    mean = s / jnp.maximum(cnt, 1.0)[:, None]
    return jnp.where(cnt[:, None] > 0, mean, fallback), cnt


# ----------------------------------------------------------------------------
# Phase 1 — windowed k-means ("E-step replaced by the window indicator")
# ----------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("iters",))
def phase1_movements(X: jnp.ndarray, win: KWindows, *, iters: int = 20) -> KWindows:
    def step(win, _):
        member = window_membership(X, win).astype(X.dtype)
        centers, cnt = _masked_mean(X, member, win.centers)
        return KWindows(centers, win.halfwidths, win.alive, cnt), None

    win, _ = jax.lax.scan(step, win, None, length=iters)
    return win


# ----------------------------------------------------------------------------
# Phase 2 — enlargement, gated on relative capture gain θ_e
# ----------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("rounds",))
def phase2_enlargement(
    X: jnp.ndarray,
    win: KWindows,
    *,
    enlarge_factor: float = 1.25,
    theta_e: float = 1.05,
    rounds: int = 8,
) -> KWindows:
    """Grow each window per-coordinate while capture grows ≥ θ_e×.

    Implements the [61] criterion the paper quotes ("the number of newly
    added examples to be above a given threshold") as a relative ratio, with
    re-centering (movement) after each accepted enlargement.
    """
    d = X.shape[1]

    def try_coord(win, coord):
        member = window_membership(X, win)
        old_cnt = jnp.sum(member, axis=0).astype(jnp.float32)  # (K,)
        h_new = win.halfwidths.at[:, coord].mul(enlarge_factor)
        cand = KWindows(win.centers, h_new, win.alive, win.counts)
        new_cnt = jnp.sum(window_membership(X, cand), axis=0).astype(jnp.float32)
        accept = new_cnt >= theta_e * jnp.maximum(old_cnt, 1.0)  # (K,)
        h = jnp.where(accept[:, None] & (jnp.arange(d) == coord)[None, :],
                      h_new, win.halfwidths)
        win = KWindows(win.centers, h, win.alive, win.counts)
        # movement after enlargement (paper: enlargement is followed by
        # recentering; a cluster whose centroid drifts too far rejects)
        member = window_membership(X, win).astype(X.dtype)
        centers, cnt = _masked_mean(X, member, win.centers)
        return KWindows(centers, win.halfwidths, win.alive, cnt), None

    def round_(win, _):
        win, _ = jax.lax.scan(try_coord, win, jnp.arange(d))
        return win, None

    win, _ = jax.lax.scan(round_, win, None, length=rounds)
    return win


# ----------------------------------------------------------------------------
# Phase 3 — merging, gated on overlap ratio θ_m
# ----------------------------------------------------------------------------

def _overlap_counts(X: jnp.ndarray, win: KWindows) -> jnp.ndarray:
    member = window_membership(X, win).astype(jnp.float32)  # (N, K)
    return member.T @ member  # (K, K) pairwise joint-capture counts


@jax.jit
def phase3_merging(X: jnp.ndarray, win: KWindows, *, theta_m: float = 0.5) -> KWindows:
    """Merge pairs whose shared-capture ratio exceeds θ_m.

    ratio(i,j) = card(W_i ∩ W_j captured) / min(card_i, card_j); merged
    cluster = count-weighted center, union box.  Candidate pairs are
    pre-filtered by the paper's dist(c_i,c_j) < 2·max radius test.
    """
    K = win.centers.shape[0]
    joint = _overlap_counts(X, win)
    cnt = jnp.diag(joint)

    cdist = jnp.sqrt(
        jnp.sum((win.centers[:, None, :] - win.centers[None, :, :]) ** 2, axis=-1)
    )
    rad = jnp.max(win.halfwidths, axis=1)
    near = cdist < 2.0 * jnp.maximum(rad[:, None], rad[None, :])

    ratio = joint / jnp.maximum(jnp.minimum(cnt[:, None], cnt[None, :]), 1.0)
    mergeable = (
        (ratio > theta_m)
        & near
        & (win.alive[:, None] > 0)
        & (win.alive[None, :] > 0)
        & (jnp.triu(jnp.ones((K, K)), k=1) > 0)
    )

    def body(carry, i):
        centers, half, alive, counts = carry
        row = mergeable[i] & (alive > 0)
        j = jnp.argmax(row)
        do = jnp.any(row) & (alive[i] > 0)
        tot = counts[i] + counts[j]
        c = (centers[i] * counts[i] + centers[j] * counts[j]) / jnp.maximum(tot, 1.0)
        lo = jnp.minimum(centers[i] - half[i], centers[j] - half[j])
        hi = jnp.maximum(centers[i] + half[i], centers[j] + half[j])
        centers = jnp.where(do, centers.at[i].set(c), centers)
        half = jnp.where(do, half.at[i].set(jnp.maximum((hi - lo) / 2.0, 1e-12)), half)
        counts = jnp.where(do, counts.at[i].set(tot).at[j].set(0.0), counts)
        alive = jnp.where(do, alive.at[j].set(0.0), alive)
        return (centers, half, alive, counts), None

    carry0 = (win.centers, win.halfwidths, win.alive, win.counts)
    (centers, half, alive, counts), _ = jax.lax.scan(body, carry0, jnp.arange(K))
    return KWindows(centers, half, alive, counts)


# ----------------------------------------------------------------------------
# Full pipeline + distributed variant
# ----------------------------------------------------------------------------

def init_windows(key: jax.Array, X: jnp.ndarray, K: int, r: float) -> KWindows:
    """Initial square windows of edge 2r centered on random data points."""
    idx = jax.random.choice(key, X.shape[0], shape=(K,), replace=False)
    centers = X[idx]
    half = jnp.full((K, X.shape[1]), r)
    return KWindows(centers, half, jnp.ones((K,)), jnp.zeros((K,)))


def kwindows(
    key: jax.Array,
    X: jnp.ndarray,
    *,
    num_windows: int,
    r: float,
    theta_e: float = 1.05,
    theta_m: float = 0.5,
    p1_iters: int = 20,
    p2_rounds: int = 6,
) -> KWindows:
    """The three-phase k-windows algorithm (start with many windows; the
    merge phase converges toward the natural cluster count — the paper's
    random over-initialization procedure)."""
    win = init_windows(key, X, num_windows, r)
    win = phase1_movements(X, win, iters=p1_iters)
    win = phase2_enlargement(X, win, theta_e=theta_e, rounds=p2_rounds)
    win = phase3_merging(X, win, theta_m=theta_m)
    # refresh counts after merging
    member = window_membership(X, win).astype(X.dtype)
    cnt = jnp.sum(member, axis=0)
    return KWindows(win.centers, win.halfwidths, win.alive * (cnt > 0), cnt)


def boxes_overlap(win: KWindows) -> jnp.ndarray:
    """(K, K) pairwise geometric box-overlap indicator."""
    lo = win.centers - win.halfwidths
    hi = win.centers + win.halfwidths
    sep = jnp.any(
        (lo[:, None, :] > hi[None, :, :]) | (hi[:, None, :] < lo[None, :, :]),
        axis=-1,
    )
    return (
        (~sep)
        & (win.alive[:, None] > 0)
        & (win.alive[None, :] > 0)
    )


def merge_overlapping_windows(win: KWindows, *, sweeps: int = 3) -> KWindows:
    """[60]'s naive server-side rule: merge every geometrically overlapping
    pair, regardless of shared capture counts.  Multiple sweeps collapse
    chained overlaps."""
    K = win.centers.shape[0]
    carry = (win.centers, win.halfwidths, win.alive, win.counts)
    for _ in range(sweeps):
        ov = boxes_overlap(KWindows(*carry))

        def body(carry, i, ov=ov):
            centers, half, alive, counts = carry
            row = ov[i] & (alive > 0) & (jnp.arange(K) > i)
            j = jnp.argmax(row)
            do = jnp.any(row) & (alive[i] > 0)
            tot = counts[i] + counts[j]
            c = (centers[i] * counts[i] + centers[j] * counts[j]) / jnp.maximum(tot, 1.0)
            lo = jnp.minimum(centers[i] - half[i], centers[j] - half[j])
            hi = jnp.maximum(centers[i] + half[i], centers[j] + half[j])
            centers = jnp.where(do, centers.at[i].set(c), centers)
            half = jnp.where(do, half.at[i].set(jnp.maximum((hi - lo) / 2.0, 1e-12)), half)
            counts = jnp.where(do, counts.at[i].set(tot).at[j].set(0.0), counts)
            alive = jnp.where(do, alive.at[j].set(0.0), alive)
            return (centers, half, alive, counts), None

        carry, _ = jax.lax.scan(body, carry, jnp.arange(K))
    return KWindows(*carry)


class KWindowsStrategy(Strategy):
    """[60]'s distributed k-windows as a Strategy on the unified engine.

    θ is the pooled window set (K·W slots, one block per node).  Each §5
    contact runs the full three-phase local k-windows on the node's shard
    and pushes its windows into its slot block; ``finalize`` is the naive
    server merge of ALL overlapping windows.  One round-robin pass
    (``schedules.round_robin(K, 1)``) reproduces the historical
    ``distributed_kwindows`` exactly, and the engine's Wire metering gives
    the algorithm the byte accounting it never had.
    """

    def __init__(self, key: jax.Array, *, num_windows: int, r: float, **kw):
        self.key = key
        self.num_windows = num_windows
        self.r = r
        self.kw = kw

    def num_nodes(self, data):
        return data.shape[0]

    def init_theta(self, data):
        Knodes, _, d = data.shape
        pool = Knodes * self.num_windows
        return KWindows(
            centers=jnp.zeros((pool, d)),
            halfwidths=jnp.zeros((pool, d)),
            alive=jnp.zeros((pool,)),
            counts=jnp.zeros((pool,)),
        )

    def init_state(self, theta, data):
        return jax.random.split(self.key, data.shape[0])

    def local_step(self, k, theta, state, data):
        # ``k`` indexes this executor's DATA slice; the pooled θ slots and
        # the stacked per-node keys are replicated, so they are indexed at
        # the node's global position (identical locally, where kg == k —
        # this is what lets the sequential schedule place on a mesh)
        kg = _exec.node_global_index(k)
        win = kwindows(
            state[kg], data[k], num_windows=self.num_windows, r=self.r, **self.kw
        )
        start = kg * self.num_windows
        pool = KWindows(
            centers=jax.lax.dynamic_update_slice(theta.centers, win.centers, (start, 0)),
            halfwidths=jax.lax.dynamic_update_slice(
                theta.halfwidths, win.halfwidths, (start, 0)
            ),
            alive=jax.lax.dynamic_update_slice(theta.alive, win.alive, (start,)),
            counts=jax.lax.dynamic_update_slice(theta.counts, win.counts, (start,)),
        )
        return pool, state

    def round_metric(self, theta, state, data):
        return jnp.sum(theta.alive)

    def finalize(self, theta, state, data):
        return merge_overlapping_windows(theta)

    def predict(self, theta, X):
        """Cluster assignment of query points against the merged window
        set (``theta`` is the finalized ``KWindows``): nearest capturing
        window's index, or -1 for points no window captures."""
        return assign_points(X, theta)


def distributed_kwindows(
    key: jax.Array,
    Xs: jnp.ndarray,  # (Knodes, Nk, d)
    *,
    num_windows: int,
    r: float,
    ledger=None,
    **kw,
) -> KWindows:
    """[60]'s naive distributed k-windows: local runs, then the server merges
    ALL geometrically overlapping windows regardless of shared counts.

    The paper criticizes exactly this ("often leads to merging of
    neighboring clusters") — reproduced in the clustering benchmark.

    Deprecation shim → ``api.fit(KWindowsStrategy(...),
    transport="sequential_server")``.  Pass a ``CommLedger`` as ``ledger``
    to collect the protocol's byte accounting (push + handoff of the
    pooled window set per contact).
    """
    warnings.warn(
        "repro.ml.kwindows.distributed_kwindows is a deprecation shim; use "
        'repro.api.fit(KWindowsStrategy(...), Xs, transport="sequential_server")',
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.api import fit
    from repro.core.schedules import round_robin

    strategy = KWindowsStrategy(key, num_windows=num_windows, r=r, **kw)
    res = fit(
        strategy,
        Xs,
        transport="sequential_server",
        schedule=round_robin(Xs.shape[0], 1),
        tag="kwindows",
    )
    if ledger is not None:
        ledger.merge(res.ledger)
    return res.theta
