"""Distributed parameter estimation in probabilistic graphical models
(paper §3.4).

The paper surveys [38]/[42]/[43]: exact MLE in MRFs needs the intractable
partition function; the Maximum Pseudo-Likelihood Estimator (MPLE) replaces
it with per-variable conditionals — "the gradient becomes data-dependent
only, but the same parameter needs to be shared across multiple factors
(not distributed friendly)"; [38] resolves this by treating it as a
consensus optimization problem solved with ADMM.

We implement the Gaussian MRF case (precision matrix Θ): the conditional
of x_i given the rest is N(−Σ_{j≠i} (θ_ij/θ_ii) x_j, 1/θ_ii), so the
negative pseudo-log-likelihood is smooth and convex in Θ for θ_ii > 0, and
the consensus-ADMM engine from ``repro.core.admm`` applies directly —
node k holds a sample shard, the consensus variable is the shared Θ.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.admm import consensus_admm, gradient_local_prox


def _sym(theta_flat: jnp.ndarray, d: int) -> jnp.ndarray:
    """Vector (d·(d+1)/2) of upper-tri entries → symmetric (d, d)."""
    iu = jnp.triu_indices(d)
    Th = jnp.zeros((d, d)).at[iu].set(theta_flat)
    return Th + jnp.triu(Th, 1).T


def flatten_sym(Theta: jnp.ndarray) -> jnp.ndarray:
    d = Theta.shape[0]
    return Theta[jnp.triu_indices(d)]


def neg_pseudo_loglik(theta_flat: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    """−(1/N) Σ_n Σ_i log p(x_ni | x_n,−i; Θ) for a Gaussian MRF.

    log p(x_i|x_−i) = ½log θ_ii − θ_ii/2 (x_i + Σ_{j≠i} θ_ij x_j/θ_ii)²
                      − ½log 2π
                    = ½log θ_ii − (Θx)_i² / (2 θ_ii) − ½log 2π.
    A softplus keeps θ_ii > 0 along the optimization path.
    """
    N, d = X.shape
    Th = _sym(theta_flat, d)
    diag = jnp.diag(Th)
    diag_safe = jnp.maximum(diag, 1e-4)
    r = X @ Th  # (N, d): row n, col i = (Θ x_n)_i
    ll = 0.5 * jnp.log(diag_safe)[None, :] - r ** 2 / (2.0 * diag_safe)[None, :]
    barrier = jnp.sum(jax.nn.softplus(-(diag - 1e-3) * 100.0)) * 1e-2
    return -jnp.mean(jnp.sum(ll, axis=1)) + barrier


def mple_centralized(
    X: jnp.ndarray, *, iters: int = 500, lr: float = 0.05
) -> jnp.ndarray:
    """Adagrad descent on the pseudo-likelihood (reference solver)."""
    d = X.shape[1]
    theta = flatten_sym(jnp.eye(d))
    grad = jax.grad(neg_pseudo_loglik)
    acc = jnp.zeros_like(theta)

    def step(carry, _):
        th, acc = carry
        g = grad(th, X)
        acc = acc + g * g
        th = th - lr * g / (jnp.sqrt(acc) + 1e-8)
        return (th, acc), None

    (theta, _), _ = jax.lax.scan(step, (theta, acc), None, length=iters)
    return _sym(theta, d)


def mple_consensus(
    Xs: jnp.ndarray,  # (K, Nk, d) sample shards
    *,
    rho: float = 1.0,
    iters: int = 60,
    inner_iters: int = 40,
    inner_lr: float = 0.05,
):
    """[38]: distributed MPLE as a consensus problem solved with ADMM.

    Each node runs the prox of its local pseudo-likelihood (inner gradient
    loop); the z-update is the Allreduce average.  Returns (Theta, result).
    """
    K, Nk, d = Xs.shape
    dim = d * (d + 1) // 2

    def grad_f(theta_rows):
        return jax.vmap(lambda th, X: jax.grad(neg_pseudo_loglik)(th, X))(
            theta_rows, Xs
        )

    local_prox = gradient_local_prox(grad_f, inner_iters=inner_iters, lr=inner_lr)
    theta0 = jnp.tile(flatten_sym(jnp.eye(d))[None], (K, 1))
    res = consensus_admm(
        local_prox, K, dim, rho=rho, g="none", iters=iters, theta0=theta0
    )
    return _sym(res.z, d), res


def sample_gmrf(key, Theta: jnp.ndarray, n: int) -> jnp.ndarray:
    """Exact samples from N(0, Θ⁻¹) for synthetic-data experiments."""
    d = Theta.shape[0]
    cov = jnp.linalg.inv(Theta)
    L = jnp.linalg.cholesky(cov + 1e-9 * jnp.eye(d))
    z = jax.random.normal(key, (n, d))
    return z @ L.T


def support_f1(Theta_hat: jnp.ndarray, Theta_true: jnp.ndarray, thresh=0.1):
    """Edge-recovery F1 between estimated and true off-diagonal supports."""
    d = Theta_true.shape[0]
    mask = ~jnp.eye(d, dtype=bool)
    pred = (jnp.abs(Theta_hat) > thresh) & mask
    true = (jnp.abs(Theta_true) > 1e-9) & mask
    tp = jnp.sum(pred & true)
    prec = tp / jnp.maximum(jnp.sum(pred), 1)
    rec = tp / jnp.maximum(jnp.sum(true), 1)
    return 2 * prec * rec / jnp.maximum(prec + rec, 1e-9)
