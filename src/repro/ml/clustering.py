"""Distributed clustering (paper §4.1).

* ``kmeans``                 — EM-style k-means with configurable assignment
                               metric ℓ1 / ℓ2 / ℓ∞ (the paper's §4.2 link to
                               Laplace / Gaussian / uniform ML priors), and
                               metric-matched M-steps (median / mean /
                               midrange).
* ``distributed_kmeans``     — sufficient-statistics form: nodes push only
                               per-cluster (Σx, count); one Allreduce per EM
                               iteration; provably identical to centralized
                               k-means on the union (tested).
* ``consensus_kmeans``       — [21]: ADMM consensus on the centroid matrix.
* ``summarize_representatives`` — [30]-style density summarization: each
                               node transmits a small set of representative
                               points (every representative has ≥ min_pts
                               neighbors within eps; neighborhoods do not
                               overlap); global clustering runs server-side
                               on representatives only.
* ``radius_t_clustering``    — [27]: dynamic local clusters of maximum
                               radius T; centroids + summary statistics are
                               pushed, and the server merges clusters whose
                               centroids are closer than T.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------------------
# Distances
# ----------------------------------------------------------------------------

def pdist(X: jnp.ndarray, C: jnp.ndarray, metric: str = "l2") -> jnp.ndarray:
    """Pairwise distances (N, K) between points X (N,d) and centroids C (K,d).

    The compute hot spot of every E-step; has a Pallas TPU kernel
    (``repro.kernels.pdist_argmin``) — this is the reference path.
    """
    diff = X[:, None, :] - C[None, :, :]
    if metric == "l2":
        return jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))
    if metric == "l2sq":
        return jnp.sum(diff * diff, axis=-1)
    if metric == "l1":
        return jnp.sum(jnp.abs(diff), axis=-1)
    if metric == "linf":
        return jnp.max(jnp.abs(diff), axis=-1)
    raise ValueError(f"unknown metric {metric!r}")


def kmeans_pp_init(key: jax.Array, X: jnp.ndarray, K: int) -> jnp.ndarray:
    """k-means++ seeding: iteratively pick centers ∝ squared distance to the
    nearest already-chosen center (fixed-shape, jit-safe)."""
    N = X.shape[0]
    k0, key = jax.random.split(key)
    first = X[jax.random.randint(k0, (), 0, N)]
    C = jnp.tile(first[None], (K, 1))

    def body(carry, i):
        C, key = carry
        d2 = jnp.min(pdist(X, C, metric="l2sq"), axis=1)
        key, kc = jax.random.split(key)
        idx = jax.random.categorical(kc, jnp.log(jnp.maximum(d2, 1e-12)))
        C = C.at[i].set(X[idx])
        return (C, key), None

    (C, _), _ = jax.lax.scan(body, (C, key), jnp.arange(1, K))
    return C


class KMeansResult(NamedTuple):
    centroids: jnp.ndarray  # (K, d)
    assignments: jnp.ndarray  # (N,)
    inertia: jnp.ndarray  # scalar
    iters: int


def _m_step(X, assign, K, metric):
    onehot = jax.nn.one_hot(assign, K, dtype=X.dtype)  # (N, K)
    counts = jnp.sum(onehot, axis=0)  # (K,)
    if metric in ("l2", "l2sq"):
        sums = onehot.T @ X
        return sums / jnp.maximum(counts, 1.0)[:, None], counts
    if metric == "l1":
        # coordinate-wise median of assigned points (masked)
        def med(k):
            m = onehot[:, k]
            big = 1e30
            Xm = jnp.where(m[:, None] > 0, X, big)
            n_k = jnp.sum(m)
            srt = jnp.sort(Xm, axis=0)
            lo = jnp.maximum((n_k - 1) // 2, 0).astype(jnp.int32)
            hi = (n_k // 2).astype(jnp.int32)
            return 0.5 * (srt[lo] + srt[hi])

        meds = jax.vmap(med)(jnp.arange(K))
        fallback = (onehot.T @ X) / jnp.maximum(counts, 1.0)[:, None]
        return jnp.where(counts[:, None] > 0, meds, fallback), counts
    if metric == "linf":
        # midrange: (min + max)/2 of assigned points, per coordinate
        big = 1e30

        def midrange(k):
            m = onehot[:, k][:, None]
            mn = jnp.min(jnp.where(m > 0, X, big), axis=0)
            mx = jnp.max(jnp.where(m > 0, X, -big), axis=0)
            return 0.5 * (mn + mx)

        mids = jax.vmap(midrange)(jnp.arange(K))
        fallback = (onehot.T @ X) / jnp.maximum(counts, 1.0)[:, None]
        return jnp.where(counts[:, None] > 0, mids, fallback), counts
    raise ValueError(metric)


@partial(jax.jit, static_argnames=("num_clusters", "metric", "iters"))
def kmeans(
    X: jnp.ndarray,
    init_centroids: jnp.ndarray,
    *,
    num_clusters: int,
    metric: str = "l2",
    iters: int = 50,
) -> KMeansResult:
    K = num_clusters

    def step(C, _):
        d = pdist(X, C, metric=metric)
        assign = jnp.argmin(d, axis=1)
        C_new, _ = _m_step(X, assign, K, metric)
        return C_new, None

    C, _ = jax.lax.scan(step, init_centroids, None, length=iters)
    d = pdist(X, C, metric=metric)
    assign = jnp.argmin(d, axis=1)
    inertia = jnp.sum(jnp.min(pdist(X, C, metric="l2sq"), axis=1))
    return KMeansResult(centroids=C, assignments=assign, inertia=inertia, iters=iters)


# ----------------------------------------------------------------------------
# Sufficient-statistics distributed k-means
# ----------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("num_clusters", "iters"))
def distributed_kmeans(
    Xs: jnp.ndarray,  # (Knodes, Nk, d)
    init_centroids: jnp.ndarray,
    *,
    num_clusters: int,
    iters: int = 50,
) -> KMeansResult:
    """Each node pushes per-cluster (Σx, count); the server aggregates.

    One Allreduce of (K·d + K) numbers per EM iteration — independent of the
    local dataset sizes.  Identical trajectory to centralized ℓ2 k-means on
    the union of shards.
    """
    K = num_clusters

    def local_stats(X, C):
        d = pdist(X, C, metric="l2sq")
        assign = jnp.argmin(d, axis=1)
        onehot = jax.nn.one_hot(assign, K, dtype=X.dtype)
        return onehot.T @ X, jnp.sum(onehot, axis=0)  # (K,d), (K,)

    def step(C, _):
        sums, counts = jax.vmap(local_stats, in_axes=(0, None))(Xs, C)
        g_sums = jnp.sum(sums, axis=0)  # Allreduce
        g_counts = jnp.sum(counts, axis=0)  # Allreduce
        C_new = g_sums / jnp.maximum(g_counts, 1.0)[:, None]
        C_new = jnp.where(g_counts[:, None] > 0, C_new, C)
        return C_new, None

    C, _ = jax.lax.scan(step, init_centroids, None, length=iters)
    Xall = Xs.reshape(-1, Xs.shape[-1])
    assign = jnp.argmin(pdist(Xall, C, metric="l2sq"), axis=1)
    inertia = jnp.sum(jnp.min(pdist(Xall, C, metric="l2sq"), axis=1))
    return KMeansResult(centroids=C, assignments=assign, inertia=inertia, iters=iters)


# ----------------------------------------------------------------------------
# Consensus k-means via ADMM ([21])
# ----------------------------------------------------------------------------

def consensus_kmeans(
    Xs: jnp.ndarray,
    init_centroids: jnp.ndarray,
    *,
    rho: float = 0.1,
    iters: int = 60,
    local_em_iters: int = 3,
):
    """ADMM consensus on the flattened centroid matrix.

    Local prox: a few EM steps on the node's shard pulled toward the
    consensus centroids (quadratic penalty has a closed-form blend:
    weighted average of local cluster mean and the consensus value,
    weights = local count vs ρ), followed by a greedy slot re-alignment to
    the consensus — consensus on a SET of centroids is only defined up to
    per-node permutation, and without alignment nodes that discover the
    clusters in different slot orders make the z-average meaningless.
    """
    Knodes, Nk, d = Xs.shape
    K = init_centroids.shape[0]
    dim = K * d

    def _align(C, V):
        """Greedily permute rows of C to match rows of V (K is small)."""
        d2 = jnp.sum((V[:, None, :] - C[None, :, :]) ** 2, axis=-1)  # (K, K)

        def pick(carry, i):
            d2m, perm = carry
            j = jnp.argmin(d2m[i])
            perm = perm.at[i].set(j)
            d2m = d2m.at[:, j].set(jnp.inf)
            return (d2m, perm), None

        (_, perm), _ = jax.lax.scan(
            pick, (d2, jnp.zeros((K,), jnp.int32)), jnp.arange(K)
        )
        return C[perm]

    def local_prox(v_flat, u, rho_):
        def one(v_row, X):
            V = v_row.reshape(K, d)
            C = V

            def em(C, _):
                dd = pdist(X, C, metric="l2sq")
                assign = jnp.argmin(dd, axis=1)
                onehot = jax.nn.one_hot(assign, K, dtype=X.dtype)
                counts = jnp.sum(onehot, axis=0)
                sums = onehot.T @ X
                # argmin Σ‖x−c‖² + (ρ/2)‖c−v‖² → (Σx + ρ/2·v) / (n + ρ/2)
                C_new = (sums + 0.5 * rho_ * V) / (counts[:, None] + 0.5 * rho_)
                return C_new, None

            C, _ = jax.lax.scan(em, C, None, length=local_em_iters)
            return _align(C, V).reshape(-1)

        return jax.vmap(one)(v_flat, Xs)

    from repro.core.admm import consensus_admm

    theta0 = jnp.tile(init_centroids.reshape(1, -1), (Knodes, 1))
    res = consensus_admm(
        local_prox, Knodes, dim, rho=rho, g="none", iters=iters, theta0=theta0
    )
    return res.z.reshape(K, d), res


# ----------------------------------------------------------------------------
# Representative-point summarization ([30], DBSCAN-flavored)
# ----------------------------------------------------------------------------

def summarize_representatives(
    X: jnp.ndarray,
    *,
    eps: float,
    min_pts: int,
    max_reps: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Greedy core-point cover: every representative has ≥ min_pts neighbors
    within eps and covered neighborhoods do not overlap.

    Returns ``(reps, mask)`` with fixed shape (max_reps, d) / (max_reps,).
    """
    N, d = X.shape
    D = pdist(X, X, metric="l2")
    neigh = D <= eps  # (N, N)
    counts0 = jnp.sum(neigh, axis=1)

    def body(carry, _):
        covered, reps, mask, slot = carry
        counts = jnp.sum(neigh & ~covered[None, :], axis=1)
        counts = jnp.where(covered, -1, counts)
        best = jnp.argmax(counts)
        ok = counts[best] >= min_pts
        covered = jnp.where(ok, covered | neigh[best], covered)
        reps = jnp.where(ok, reps.at[slot].set(X[best]), reps)
        mask = jnp.where(ok, mask.at[slot].set(1.0), mask)
        slot = slot + jnp.where(ok, 1, 0)
        return (covered, reps, mask, slot), None

    covered0 = counts0 < min_pts  # noise points never become reps
    carry0 = (
        covered0,
        jnp.zeros((max_reps, d)),
        jnp.zeros((max_reps,)),
        jnp.asarray(0),
    )
    (covered, reps, mask, _), _ = jax.lax.scan(body, carry0, None, length=max_reps)
    return reps, mask


# ----------------------------------------------------------------------------
# Radius-T incremental clustering ([27])
# ----------------------------------------------------------------------------

def radius_t_clustering(
    X: jnp.ndarray, *, T: float, max_clusters: int
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One pass: assign each point to the nearest existing centroid if within
    T, else open a new cluster (up to ``max_clusters``; overflow folds into
    the nearest).  Returns (centroids, counts, mask)."""
    N, d = X.shape

    def body(carry, x):
        C, counts, ncl = carry
        dd = jnp.where(
            jnp.arange(max_clusters) < ncl,
            jnp.sqrt(jnp.sum((C - x[None, :]) ** 2, axis=1)),
            jnp.inf,
        )
        j = jnp.argmin(dd)
        near = dd[j] <= T
        can_open = ncl < max_clusters
        open_new = (~near) & can_open
        tgt = jnp.where(open_new, ncl, j)
        new_count = counts[tgt] + 1.0
        # running mean update
        C = C.at[tgt].set(C[tgt] + (x - C[tgt]) / new_count)
        counts = counts.at[tgt].set(new_count)
        ncl = ncl + jnp.where(open_new, 1, 0)
        return (C, counts, ncl), None

    carry0 = (jnp.zeros((max_clusters, d)), jnp.zeros((max_clusters,)), jnp.asarray(0))
    (C, counts, ncl), _ = jax.lax.scan(body, carry0, X)
    mask = (jnp.arange(max_clusters) < ncl).astype(jnp.float32)
    return C, counts, mask


def merge_centroids(
    C: jnp.ndarray, counts: jnp.ndarray, mask: jnp.ndarray, *, T: float
):
    """Server-side merge: greedily fold together centroids closer than T
    (count-weighted means) — the aggregation step of [27]."""
    Kc = C.shape[0]

    def body(carry, i):
        C, counts, mask = carry
        dd = jnp.sqrt(jnp.sum((C - C[i][None, :]) ** 2, axis=1))
        cand = (dd <= T) & (mask > 0) & (jnp.arange(Kc) > i) & (mask[i] > 0)
        j = jnp.argmax(cand)
        do = jnp.any(cand)
        tot = counts[i] + counts[j]
        merged = (C[i] * counts[i] + C[j] * counts[j]) / jnp.maximum(tot, 1.0)
        C = jnp.where(do, C.at[i].set(merged), C)
        counts = jnp.where(do, counts.at[i].set(tot).at[j].set(0.0), counts)
        mask = jnp.where(do, mask.at[j].set(0.0), mask)
        return (C, counts, mask), None

    (C, counts, mask), _ = jax.lax.scan(body, (C, counts, mask), jnp.arange(Kc))
    return C, counts, mask
