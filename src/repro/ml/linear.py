"""Distributed linear & logistic regression (paper §3.1).

Implements every §3.1 technique the paper surveys, all under the strict
client-server model with byte-accurate communication accounting:

* ``distributed_gd``          — full-batch GD with one Allreduce per step
                                (the [47]/[5] pattern: push local gradient,
                                receive global aggregate).
* ``admm_lasso``              — consensus LASSO via Douglas-Rachford/ADMM,
                                closed-form local prox (ridge subproblem).
* ``distributed_lbfgs``       — [5]'s design: ONE Allreduce per iteration
                                (the global gradient); the L-BFGS two-loop
                                recursion and rank-1 history live locally and
                                identically on every node.
* ``private_second_order``    — [6]'s privacy scheme: nodes transmit only the
                                empirical second-order statistics
                                W^(k)=X^(k)ᵀX^(k), V^(k)=X^(k)ᵀY^(k);
                                θ = (ΣW^(k))⁻¹ ΣV^(k) without any raw data
                                leaving a node.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.admm import consensus_admm, ADMMResult
from repro.core.allreduce import CommLedger, server_allreduce


# ----------------------------------------------------------------------------
# Losses
# ----------------------------------------------------------------------------

def lsq_loss(theta: jnp.ndarray, X: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """0.5‖y − Xθ‖² / N (the paper's linear-regression f)."""
    r = X @ theta - y
    return 0.5 * jnp.mean(r * r)


def logistic_loss(theta: jnp.ndarray, X: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Mean logistic loss, labels y ∈ {-1, +1}."""
    margins = y * (X @ theta)
    return jnp.mean(jnp.logaddexp(0.0, -margins))


# ----------------------------------------------------------------------------
# Allreduce gradient descent ([47], [5])
# ----------------------------------------------------------------------------

class GDResult(NamedTuple):
    theta: jnp.ndarray
    losses: jnp.ndarray
    ledger: CommLedger


def distributed_gd(
    Xs: jnp.ndarray,  # (K, Nk, n) per-node design matrices
    ys: jnp.ndarray,  # (K, Nk)
    *,
    loss: Callable = lsq_loss,
    lr: float = 0.1,
    steps: int = 200,
    l2: float = 0.0,
    theta0: jnp.ndarray | None = None,
) -> GDResult:
    """Synchronous distributed GD: one Allreduce of the gradient per step.

    Per-node gradients are computed in parallel (vmap = the K workers), then
    aggregated by the simulated central server — exactly the two-phase
    Allreduce of the paper's §3.1.
    """
    K, Nk, n = Xs.shape
    theta = jnp.zeros((n,)) if theta0 is None else theta0

    total = K * Nk
    weights = jnp.full((K,), Nk / total)  # equal shards here

    grad_local = jax.vmap(jax.grad(loss), in_axes=(None, 0, 0))

    def step(theta, _):
        gs = grad_local(theta, Xs, ys)  # (K, n) — parallel at nodes
        g = server_allreduce(gs * weights[:, None], op="sum") + l2 * theta
        theta_new = theta - lr * g
        cur = jnp.mean(jax.vmap(loss, in_axes=(None, 0, 0))(theta_new, Xs, ys))
        return theta_new, cur

    theta, losses = jax.lax.scan(step, theta, None, length=steps)

    ledger = CommLedger()
    for _ in range(steps):
        ledger.record_allreduce(theta, K, tag="grad")
    return GDResult(theta=theta, losses=losses, ledger=ledger)


# ----------------------------------------------------------------------------
# Consensus LASSO via ADMM (Douglas-Rachford splitting, §3.1)
# ----------------------------------------------------------------------------

def admm_lasso(
    Xs: jnp.ndarray,
    ys: jnp.ndarray,
    *,
    lam: float = 0.1,
    rho: float = 1.0,
    iters: int = 200,
) -> ADMMResult:
    """Distributed LASSO: min Σ_k 0.5‖y_k − X_k θ‖² + λ‖θ‖₁.

    The local prox is the ridge-regularized least-squares closed form
    ``(X_kᵀX_k + ρI)⁻¹ (X_kᵀy_k + ρ v)`` — cached factorizations, carried
    "in parallel at each node"; the z-update soft-threshold is the global
    regularizer's prox at the server.
    """
    K, Nk, n = Xs.shape
    XtX = jnp.einsum("kni,knj->kij", Xs, Xs)  # (K, n, n)
    Xty = jnp.einsum("kni,kn->ki", Xs, ys)  # (K, n)

    def local_prox(v, u, rho_):
        A = XtX + rho_ * jnp.eye(n)[None]
        b = Xty + rho_ * v
        return jax.vmap(jnp.linalg.solve)(A, b)

    return consensus_admm(
        local_prox, K, n, rho=rho, g="l1", g_lam=lam, iters=iters
    )


def centralized_lasso_objective(theta, X, y, lam):
    return 0.5 * jnp.sum((X @ theta - y) ** 2) + lam * jnp.sum(jnp.abs(theta))


def ista_lasso(X, y, lam, iters=2000):
    """Centralized ISTA reference for validating the distributed solution."""
    L = jnp.linalg.norm(X, 2) ** 2
    theta = jnp.zeros(X.shape[1])

    def step(theta, _):
        g = X.T @ (X @ theta - y)
        v = theta - g / L
        theta = jnp.sign(v) * jnp.maximum(jnp.abs(v) - lam / L, 0.0)
        return theta, None

    theta, _ = jax.lax.scan(step, theta, None, length=iters)
    return theta


# ----------------------------------------------------------------------------
# Distributed L-BFGS ([5]: one Allreduce per iteration)
# ----------------------------------------------------------------------------

class LBFGSResult(NamedTuple):
    theta: jnp.ndarray
    losses: jnp.ndarray
    ledger: CommLedger


def distributed_lbfgs(
    Xs: jnp.ndarray,
    ys: jnp.ndarray,
    *,
    loss: Callable = logistic_loss,
    history: int = 8,
    steps: int = 60,
    lr: float = 1.0,
    l2: float = 1e-4,
) -> LBFGSResult:
    """L-BFGS where only the GRADIENT crosses the network.

    Every node evaluates the gradient on its shard; one Allreduce forms the
    global gradient.  The (s, y) rank-1 history and the two-loop recursion
    are maintained locally — and deterministically identically — on every
    node, so no further synchronization is needed (the [5] construction).
    """
    K, Nk, n = Xs.shape
    m = history

    grad_local = jax.vmap(jax.grad(loss), in_axes=(None, 0, 0))

    def global_grad(theta):
        gs = grad_local(theta, Xs, ys)  # parallel at nodes
        return server_allreduce(gs, op="mean") + l2 * theta  # Allreduce

    def two_loop(g, S, Y, rho, valid):
        """Standard L-BFGS two-loop recursion with a validity mask."""

        def bwd(carry, inp):
            q, = carry
            s, yv, r, v = inp
            alpha = jnp.where(v > 0, r * jnp.dot(s, q), 0.0)
            q = q - alpha * yv * jnp.where(v > 0, 1.0, 0.0)
            return (q,), alpha

        (q,), alphas = jax.lax.scan(
            bwd, (g,), (S[::-1], Y[::-1], rho[::-1], valid[::-1])
        )
        # initial Hessian scaling γ = sᵀy / yᵀy of most recent valid pair
        num = jnp.sum(S * Y, axis=1)
        den = jnp.sum(Y * Y, axis=1)
        gamma = jnp.where(
            jnp.any(valid > 0),
            jnp.sum(jnp.where(valid > 0, num, 0.0))
            / jnp.maximum(jnp.sum(jnp.where(valid > 0, den, 0.0)), 1e-12),
            1.0,
        )
        r_vec = gamma * q

        def fwd(carry, inp):
            (r_v,) = carry
            s, yv, r, v, alpha = inp
            beta = jnp.where(v > 0, r * jnp.dot(yv, r_v), 0.0)
            r_v = r_v + (alpha - beta) * s * jnp.where(v > 0, 1.0, 0.0)
            return (r_v,), None

        (r_vec,), _ = jax.lax.scan(
            fwd, (r_vec,), (S, Y, rho, valid, alphas[::-1])
        )
        return r_vec

    def step(carry, _):
        theta, g, S, Y, rho, valid, it = carry
        d = -two_loop(g, S, Y, rho, valid)
        theta_new = theta + lr * d
        g_new = global_grad(theta_new)
        s = theta_new - theta
        yv = g_new - g
        sy = jnp.dot(s, yv)
        ok = sy > 1e-10  # curvature condition
        S = jnp.where(ok, jnp.roll(S, -1, axis=0).at[-1].set(s), S)
        Y = jnp.where(ok, jnp.roll(Y, -1, axis=0).at[-1].set(yv), Y)
        rho = jnp.where(ok, jnp.roll(rho, -1).at[-1].set(1.0 / jnp.maximum(sy, 1e-12)), rho)
        valid = jnp.where(ok, jnp.roll(valid, -1).at[-1].set(1.0), valid)
        cur = jnp.mean(jax.vmap(loss, in_axes=(None, 0, 0))(theta_new, Xs, ys))
        return (theta_new, g_new, S, Y, rho, valid, it + 1), cur

    theta0 = jnp.zeros((n,))
    g0 = global_grad(theta0)
    carry0 = (
        theta0,
        g0,
        jnp.zeros((m, n)),
        jnp.zeros((m, n)),
        jnp.zeros((m,)),
        jnp.zeros((m,)),
        jnp.asarray(0),
    )
    (theta, *_), losses = jax.lax.scan(step, carry0, None, length=steps)

    ledger = CommLedger()
    for _ in range(steps + 1):
        ledger.record_allreduce(theta, K, tag="grad")
    return LBFGSResult(theta=theta, losses=losses, ledger=ledger)


# ----------------------------------------------------------------------------
# Privacy-preserving regression via second-order statistics ([6])
# ----------------------------------------------------------------------------

def private_second_order(Xs: jnp.ndarray, ys: jnp.ndarray, l2: float = 0.0):
    """θ = (Σ_k X_kᵀX_k + l2·I)⁻¹ Σ_k X_kᵀy_k — only the (n×n)+(n,) statistics
    are transmitted; raw data points never leave a node.

    Returns ``(theta, ledger)``; the ledger shows the wire cost is K·(n²+n)
    numbers, independent of the dataset size N — the paper's point about
    "masking exact data point values".
    """
    K, Nk, n = Xs.shape
    Wk = jnp.einsum("kni,knj->kij", Xs, Xs)  # computed at nodes
    Vk = jnp.einsum("kni,kn->ki", Xs, ys)
    W = server_allreduce(Wk, op="sum") + l2 * jnp.eye(n)
    V = server_allreduce(Vk, op="sum")
    theta = jnp.linalg.solve(W, V)

    ledger = CommLedger()
    ledger.record_push((Wk, Vk), tag="second-order-stats")
    ledger.record_pull(theta, tag="theta")
    return theta, ledger
