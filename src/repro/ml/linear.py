"""Distributed linear & logistic regression (paper §3.1).

All §3.1 techniques, now expressed on the unified ``repro.api`` engine:

* ``distributed_gd``          — deprecation shim →
  ``api.fit(GradientDescent(...), transport="allreduce")``;
* ``admm_lasso``              — deprecation shim →
  ``api.fit(ProxStrategy(...), transport="admm_consensus", g="l1")``;
* ``distributed_lbfgs``       — deprecation shim →
  ``api.fit(LBFGS(...), transport="allreduce")`` ([5]: ONE Allreduce per
  iteration; history + two-loop live in ``repro.api.strategy.LBFGS``);
* ``private_second_order``    — [6]'s privacy scheme: nodes transmit only
  W^(k)=X^(k)ᵀX^(k), V^(k)=X^(k)ᵀY^(k); byte cost metered by the Wire
  layer.

The shims keep the historical signatures and result types; new code
should call ``repro.api.fit`` directly (see docs/API.md).
"""

from __future__ import annotations

import warnings
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.api import fit
from repro.api.strategy import GradientDescent, LBFGS, ProxStrategy
from repro.core.admm import ADMMResult
from repro.core.allreduce import CommLedger, server_allreduce


def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"repro.ml.linear.{old} is a deprecation shim; use {new}",
        DeprecationWarning,
        stacklevel=3,
    )


# ----------------------------------------------------------------------------
# Losses
# ----------------------------------------------------------------------------

def lsq_loss(theta: jnp.ndarray, X: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """0.5‖y − Xθ‖² / N (the paper's linear-regression f)."""
    r = X @ theta - y
    return 0.5 * jnp.mean(r * r)


def logistic_loss(theta: jnp.ndarray, X: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Mean logistic loss, labels y ∈ {-1, +1}."""
    margins = y * (X @ theta)
    return jnp.mean(jnp.logaddexp(0.0, -margins))


# ----------------------------------------------------------------------------
# Allreduce gradient descent ([47], [5]) — shim over the unified engine
# ----------------------------------------------------------------------------

class GDResult(NamedTuple):
    theta: jnp.ndarray
    losses: jnp.ndarray
    ledger: CommLedger


def distributed_gd(
    Xs: jnp.ndarray,  # (K, Nk, n) per-node design matrices
    ys: jnp.ndarray,  # (K, Nk)
    *,
    loss: Callable = lsq_loss,
    lr: float = 0.1,
    steps: int = 200,
    l2: float = 0.0,
    theta0: jnp.ndarray | None = None,
) -> GDResult:
    """Synchronous distributed GD: one Allreduce of the gradient per step."""
    _deprecated(
        "distributed_gd",
        'repro.api.fit(GradientDescent(loss), data, transport="allreduce")',
    )
    res = fit(
        GradientDescent(loss, lr=lr, l2=l2),
        (Xs, ys),
        transport="allreduce",
        steps=steps,
        theta0=theta0,
        tag="gd",
    )
    return GDResult(theta=res.theta, losses=res.trajectory, ledger=res.ledger)


# ----------------------------------------------------------------------------
# Consensus LASSO via ADMM (Douglas-Rachford splitting, §3.1)
# ----------------------------------------------------------------------------

def lasso_prox_builder(data):
    """Closed-form ridge subproblem prox, factor data precomputed per node."""
    Xs, ys = data
    n = Xs.shape[-1]
    XtX = jnp.einsum("kni,knj->kij", Xs, Xs)  # (K, n, n)
    Xty = jnp.einsum("kni,kn->ki", Xs, ys)  # (K, n)

    def local_prox(v, u, rho_):
        A = XtX + rho_ * jnp.eye(n)[None]
        b = Xty + rho_ * v
        return jax.vmap(jnp.linalg.solve)(A, b)

    return local_prox


def admm_lasso(
    Xs: jnp.ndarray,
    ys: jnp.ndarray,
    *,
    lam: float = 0.1,
    rho: float = 1.0,
    iters: int = 200,
) -> ADMMResult:
    """Distributed LASSO: min Σ_k 0.5‖y_k − X_k θ‖² + λ‖θ‖₁.

    The local prox is the ridge-regularized least-squares closed form
    ``(X_kᵀX_k + ρI)⁻¹ (X_kᵀy_k + ρ v)``; the z-update soft-threshold is
    the global regularizer's prox at the server.
    """
    _deprecated(
        "admm_lasso",
        'repro.api.fit(ProxStrategy(...), data, transport="admm_consensus", g="l1")',
    )
    res = fit(
        ProxStrategy(lasso_prox_builder),
        (Xs, ys),
        transport="admm_consensus",
        steps=iters,
        rho=rho,
        g="l1",
        g_lam=lam,
        tag="lasso",
    )
    return res.metrics["admm"]


def centralized_lasso_objective(theta, X, y, lam):
    return 0.5 * jnp.sum((X @ theta - y) ** 2) + lam * jnp.sum(jnp.abs(theta))


def ista_lasso(X, y, lam, iters=2000):
    """Centralized ISTA reference for validating the distributed solution."""
    L = jnp.linalg.norm(X, 2) ** 2
    theta = jnp.zeros(X.shape[1])

    def step(theta, _):
        g = X.T @ (X @ theta - y)
        v = theta - g / L
        theta = jnp.sign(v) * jnp.maximum(jnp.abs(v) - lam / L, 0.0)
        return theta, None

    theta, _ = jax.lax.scan(step, theta, None, length=iters)
    return theta


# ----------------------------------------------------------------------------
# Distributed L-BFGS ([5]: one Allreduce per iteration) — shim
# ----------------------------------------------------------------------------

class LBFGSResult(NamedTuple):
    theta: jnp.ndarray
    losses: jnp.ndarray
    ledger: CommLedger


def distributed_lbfgs(
    Xs: jnp.ndarray,
    ys: jnp.ndarray,
    *,
    loss: Callable = logistic_loss,
    history: int = 8,
    steps: int = 60,
    lr: float = 1.0,
    l2: float = 1e-4,
) -> LBFGSResult:
    """L-BFGS where only the GRADIENT crosses the network ([5])."""
    _deprecated(
        "distributed_lbfgs",
        'repro.api.fit(LBFGS(loss), data, transport="allreduce")',
    )
    res = fit(
        LBFGS(loss, history=history, lr=lr, l2=l2),
        (Xs, ys),
        transport="allreduce",
        steps=steps,
        tag="lbfgs",
    )
    return LBFGSResult(theta=res.theta, losses=res.trajectory, ledger=res.ledger)


# ----------------------------------------------------------------------------
# Privacy-preserving regression via second-order statistics ([6])
# ----------------------------------------------------------------------------

def private_second_order(Xs: jnp.ndarray, ys: jnp.ndarray, l2: float = 0.0):
    """θ = (Σ_k X_kᵀX_k + l2·I)⁻¹ Σ_k X_kᵀy_k — only the (n×n)+(n,) statistics
    are transmitted; raw data points never leave a node.

    Returns ``(theta, ledger)``; the ledger shows the wire cost is K·(n²+n)
    numbers, independent of the dataset size N — the paper's point about
    "masking exact data point values".
    """
    K, Nk, n = Xs.shape
    Wk = jnp.einsum("kni,knj->kij", Xs, Xs)  # computed at nodes
    Vk = jnp.einsum("kni,kn->ki", Xs, ys)
    W = server_allreduce(Wk, op="sum") + l2 * jnp.eye(n)
    V = server_allreduce(Vk, op="sum")
    theta = jnp.linalg.solve(W, V)

    ledger = CommLedger()
    ledger.record_push((Wk, Vk), tag="second-order-stats")
    ledger.record_pull(theta, tag="theta")
    return theta, ledger
