"""Distributed Support Vector Machines (paper §3.2).

* ``dual_svm``        — kernel SVM dual solved by projected gradient ascent
                        (the box-constrained QP the paper writes as
                        max_{α∈[0,1/λ]^N} α1ᵀ − α(YᵀΦΦᵀY)αᵀ).
* ``cascade_svm``     — [25]: nodes train locally, push only their Support
                        Vectors; the server retrains on the union of SVs and
                        feeds the result back; repeat until the SV set is
                        stable.  Communication = SVs only.
* ``consensus_svm``   — [22]: the primal hinge-loss consensus problem solved
                        with the shared ADMM engine (smoothed-hinge local
                        prox by inner gradient descent).
* ``weighted_dual_consensus`` — the paper's OWN §3.2 proposal ("not
                        encountered in the literature review"): a consensus
                        formulation on the dual in which each node zeroes
                        some of its local α's, with per-node weights
                        proportional to local example counts so that
                        data-rich nodes are not ignored.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.admm import consensus_admm, gradient_local_prox
from repro.core.allreduce import CommLedger


# ----------------------------------------------------------------------------
# Kernels
# ----------------------------------------------------------------------------

def linear_kernel(A: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    return A @ B.T


def rbf_kernel(A: jnp.ndarray, B: jnp.ndarray, gamma: float = 1.0) -> jnp.ndarray:
    d2 = (
        jnp.sum(A * A, axis=1)[:, None]
        - 2.0 * A @ B.T
        + jnp.sum(B * B, axis=1)[None, :]
    )
    return jnp.exp(-gamma * jnp.maximum(d2, 0.0))


# ----------------------------------------------------------------------------
# Dual SVM (single node / server-side solver)
# ----------------------------------------------------------------------------

class SVMModel(NamedTuple):
    alpha: jnp.ndarray  # (N,) dual variables
    X: jnp.ndarray  # training points (needed for kernel decisions)
    y: jnp.ndarray  # labels in {-1, +1}
    sv_mask: jnp.ndarray  # alpha > tol


def dual_svm(
    X: jnp.ndarray,
    y: jnp.ndarray,
    *,
    C: float = 1.0,
    kernel=linear_kernel,
    iters: int = 500,
    mask: jnp.ndarray | None = None,
    sv_tol: float = 1e-5,
) -> SVMModel:
    """Projected gradient ascent on the SVM dual.

    max_α 1ᵀα − ½ αᵀ Q α,  Q = (y yᵀ) ∘ K,  0 ≤ α ≤ C.

    ``mask`` marks valid rows (1) vs padding (0) so cascades can operate on
    fixed-shape padded SV sets under jit.
    """
    N = X.shape[0]
    m = jnp.ones((N,)) if mask is None else mask
    K = kernel(X, X) * m[:, None] * m[None, :]
    Q = (y[:, None] * y[None, :]) * K
    # Lipschitz constant of the gradient — power iteration (cheap, jit-safe)
    v = jnp.ones((N,)) / jnp.sqrt(N)

    def pit(v, _):
        w = Q @ v
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-12), None

    v, _ = jax.lax.scan(pit, v, None, length=20)
    L = jnp.maximum(jnp.abs(v @ (Q @ v)), 1e-6)

    def step(alpha, _):
        g = 1.0 - Q @ alpha
        alpha = jnp.clip(alpha + g / L, 0.0, C) * m
        return alpha, None

    alpha0 = jnp.zeros((N,))
    alpha, _ = jax.lax.scan(step, alpha0, None, length=iters)
    return SVMModel(alpha=alpha, X=X, y=y, sv_mask=(alpha > sv_tol) & (m > 0))


def decision_function(model: SVMModel, Xq: jnp.ndarray, kernel=linear_kernel):
    """f(x) = Σ_{i: SV} α_i y_i k(x, x_i) — only SVs contribute."""
    coeff = model.alpha * model.y * model.sv_mask
    return kernel(Xq, model.X) @ coeff


# ----------------------------------------------------------------------------
# Cascade SVM ([25])
# ----------------------------------------------------------------------------

class CascadeResult(NamedTuple):
    model: SVMModel
    rounds: int
    ledger: CommLedger
    sv_counts: list


def cascade_svm(
    Xs: jnp.ndarray,  # (K, Nk, n)
    ys: jnp.ndarray,  # (K, Nk)
    *,
    C: float = 1.0,
    kernel=linear_kernel,
    max_rounds: int = 5,
    iters: int = 500,
) -> CascadeResult:
    """Cascade SVM: only Support Vectors cross the network.

    Round r: every node trains on (local data ∪ current global SV set),
    pushes the identities of its SVs; the server retrains on the union of
    received SVs and broadcasts the new global SV set.  "The procedure is
    repeated recursively until the SVs from one round to the other do not
    change" ([25] via the paper).

    The SV sets are represented as boolean masks over the pooled dataset so
    a point is never duplicated when it is both local to a node and a global
    SV — duplication would split dual weight and inflate the SV count.  The
    communication ledger still charges only the actual SV points pushed and
    broadcast.
    """
    Knodes, Nk, n = Xs.shape
    N = Knodes * Nk
    X = Xs.reshape(N, n)
    y = ys.reshape(N)
    node_of = jnp.repeat(jnp.arange(Knodes), Nk)
    ledger = CommLedger()

    train = jax.jit(
        jax.vmap(
            lambda m: dual_svm(X, y, C=C, kernel=kernel, iters=iters, mask=m)
        )
    )
    server_train = jax.jit(
        lambda m: dual_svm(X, y, C=C, kernel=kernel, iters=iters, mask=m)
    )

    global_sv = jnp.zeros((N,), dtype=bool)
    sv_counts: list[int] = []
    rounds = 0
    server_model = None
    for r in range(max_rounds):
        rounds = r + 1
        # node k trains on: its own shard ∪ the current global SV set
        node_masks = jax.vmap(
            lambda k: ((node_of == k) | global_sv).astype(jnp.float32)
        )(jnp.arange(Knodes))
        models = train(node_masks)

        # push: each node's SVs — union at the server (still only SVs move)
        pushed = jnp.any(models.sv_mask, axis=0)
        n_pushed = int(jnp.sum(pushed))
        ledger.record_push(
            (jnp.zeros((n_pushed, n)), jnp.zeros((n_pushed,))), tag=f"svs-r{r}"
        )

        server_model = server_train(pushed.astype(jnp.float32))
        new_global = server_model.sv_mask
        count = int(jnp.sum(new_global))
        sv_counts.append(count)
        ledger.record_pull(
            (jnp.zeros((count, n)), jnp.zeros((count,))), tag=f"global-svs-r{r}"
        )

        if bool(jnp.all(new_global == global_sv)):
            break
        global_sv = new_global

    return CascadeResult(
        model=server_model, rounds=rounds, ledger=ledger, sv_counts=sv_counts
    )


# ----------------------------------------------------------------------------
# Consensus SVM via ADMM ([22])
# ----------------------------------------------------------------------------

def smooth_hinge(m: jnp.ndarray, eps: float = 0.1) -> jnp.ndarray:
    """Huberized hinge — smooth surrogate so the local prox can use gradients."""
    return jnp.where(
        m >= 1.0,
        0.0,
        jnp.where(m <= 1.0 - eps, 1.0 - m - eps / 2.0, (1.0 - m) ** 2 / (2 * eps)),
    )


def consensus_svm(
    Xs: jnp.ndarray,
    ys: jnp.ndarray,
    *,
    lam: float = 1e-2,
    rho: float = 1.0,
    iters: int = 100,
    inner_iters: int = 50,
    inner_lr: float = 0.5,
):
    """Primal consensus SVM: min Σ_k Σ_i hinge(y_i θᵀx_i) + (λ/2)‖z‖²."""
    Knodes, Nk, n = Xs.shape

    def node_grad(theta_rows):
        def one(theta, X, y):
            return jax.grad(
                lambda t: jnp.sum(smooth_hinge(y * (X @ t)))
            )(theta)

        return jax.vmap(one)(theta_rows, Xs, ys)

    local_prox = gradient_local_prox(node_grad, inner_iters=inner_iters, lr=inner_lr / Nk)
    return consensus_admm(
        local_prox, Knodes, n, rho=rho, g="l2sq", g_lam=lam, iters=iters
    )


# ----------------------------------------------------------------------------
# The paper's own proposal: weighted dual consensus
# ----------------------------------------------------------------------------

def weighted_dual_consensus(
    Xs: jnp.ndarray,
    ys: jnp.ndarray,
    *,
    C: float = 1.0,
    kernel=linear_kernel,
    iters: int = 300,
    sparsity_lam: float = 0.05,
    node_weights: jnp.ndarray | None = None,
):
    """§3.2's sketched idea, made concrete.

    Each node solves its local dual but is penalized toward a *sparse*
    α (setting local SVs to zero "to satisfy consensus"), with per-node
    weights ∝ local example counts so nodes with more margin-relevant data
    are not drowned out.  Concretely: node k maximizes

        1ᵀα − ½αᵀQ_kα − (λ/w_k)‖α‖₁   s.t. 0 ≤ α ≤ C

    (an ℓ1-penalized dual; the ℓ1 prox is a shift since α ≥ 0) and the
    global decision function sums the per-node SV expansions.
    Returns per-node models and the joint decision function closure.
    """
    Knodes, Nk, _ = Xs.shape
    if node_weights is None:
        node_weights = jnp.full((Knodes,), float(Nk))
    w = node_weights / jnp.sum(node_weights)

    def solve_node(X, y, wk):
        K = kernel(X, X)
        Q = (y[:, None] * y[None, :]) * K
        L = jnp.maximum(jnp.linalg.norm(Q, ord=jnp.inf), 1e-6)
        shift = sparsity_lam / jnp.maximum(wk * Knodes, 1e-6)

        def step(alpha, _):
            g = 1.0 - Q @ alpha - shift  # ℓ1 prox on α ≥ 0 is a shift
            return jnp.clip(alpha + g / L, 0.0, C), None

        alpha, _ = jax.lax.scan(step, jnp.zeros(X.shape[0]), None, length=iters)
        return alpha

    alphas = jax.vmap(solve_node)(Xs, ys, w)  # (K, Nk)

    def decide(Xq):
        def one(X, y, alpha):
            return kernel(Xq, X) @ (alpha * y)

        return jnp.sum(jax.vmap(one)(Xs, ys, alphas * w[:, None] * Knodes), axis=0)

    return alphas, decide
