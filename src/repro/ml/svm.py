"""Distributed Support Vector Machines (paper §3.2).

* ``dual_svm``        — kernel SVM dual solved by projected gradient ascent
                        (the box-constrained QP the paper writes as
                        max_{α∈[0,1/λ]^N} α1ᵀ − α(YᵀΦΦᵀY)αᵀ).
* ``cascade_svm``     — [25]: nodes train locally, push only their Support
                        Vectors; the server retrains on the union of SVs and
                        feeds the result back; repeat until the SV set is
                        stable.  Communication = SVs only.
* ``consensus_svm``   — [22]: the primal hinge-loss consensus problem solved
                        with the shared ADMM engine (smoothed-hinge local
                        prox by inner gradient descent).
* ``weighted_dual_consensus`` — the paper's OWN §3.2 proposal ("not
                        encountered in the literature review"): a consensus
                        formulation on the dual in which each node zeroes
                        some of its local α's, with per-node weights
                        proportional to local example counts so that
                        data-rich nodes are not ignored.
"""

from __future__ import annotations

import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import fit
from repro.api import executor as _exec
from repro.api.strategy import ProxStrategy, Strategy
from repro.core.admm import gradient_local_prox
from repro.core.allreduce import CommLedger


# ----------------------------------------------------------------------------
# Kernels
# ----------------------------------------------------------------------------

def linear_kernel(A: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    return A @ B.T


def rbf_kernel(A: jnp.ndarray, B: jnp.ndarray, gamma: float = 1.0) -> jnp.ndarray:
    d2 = (
        jnp.sum(A * A, axis=1)[:, None]
        - 2.0 * A @ B.T
        + jnp.sum(B * B, axis=1)[None, :]
    )
    return jnp.exp(-gamma * jnp.maximum(d2, 0.0))


# ----------------------------------------------------------------------------
# Dual SVM (single node / server-side solver)
# ----------------------------------------------------------------------------

class SVMModel(NamedTuple):
    alpha: jnp.ndarray  # (N,) dual variables
    X: jnp.ndarray  # training points (needed for kernel decisions)
    y: jnp.ndarray  # labels in {-1, +1}
    sv_mask: jnp.ndarray  # alpha > tol


def dual_svm(
    X: jnp.ndarray,
    y: jnp.ndarray,
    *,
    C: float = 1.0,
    kernel=linear_kernel,
    iters: int = 500,
    mask: jnp.ndarray | None = None,
    sv_tol: float = 1e-5,
) -> SVMModel:
    """Projected gradient ascent on the SVM dual.

    max_α 1ᵀα − ½ αᵀ Q α,  Q = (y yᵀ) ∘ K,  0 ≤ α ≤ C.

    ``mask`` marks valid rows (1) vs padding (0) so cascades can operate on
    fixed-shape padded SV sets under jit.
    """
    N = X.shape[0]
    m = jnp.ones((N,)) if mask is None else mask
    K = kernel(X, X) * m[:, None] * m[None, :]
    Q = (y[:, None] * y[None, :]) * K
    # Lipschitz constant of the gradient — power iteration (cheap, jit-safe)
    v = jnp.ones((N,)) / jnp.sqrt(N)

    def pit(v, _):
        w = Q @ v
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-12), None

    v, _ = jax.lax.scan(pit, v, None, length=20)
    L = jnp.maximum(jnp.abs(v @ (Q @ v)), 1e-6)

    def step(alpha, _):
        g = 1.0 - Q @ alpha
        alpha = jnp.clip(alpha + g / L, 0.0, C) * m
        return alpha, None

    alpha0 = jnp.zeros((N,))
    alpha, _ = jax.lax.scan(step, alpha0, None, length=iters)
    return SVMModel(alpha=alpha, X=X, y=y, sv_mask=(alpha > sv_tol) & (m > 0))


def decision_function(model: SVMModel, Xq: jnp.ndarray, kernel=linear_kernel):
    """f(x) = Σ_{i: SV} α_i y_i k(x, x_i) — only SVs contribute."""
    coeff = model.alpha * model.y * model.sv_mask
    return kernel(Xq, model.X) @ coeff


# ----------------------------------------------------------------------------
# Cascade SVM ([25])
# ----------------------------------------------------------------------------

class CascadeResult(NamedTuple):
    model: SVMModel
    rounds: int
    ledger: CommLedger
    sv_counts: list


class CascadeStrategy(Strategy):
    """[25]'s cascade as a Strategy on the unified engine.

    θ is the global-SV boolean mask over the pooled dataset; each round's
    message is the per-node SV mask (node k trains on its shard ∪ the
    current global SVs), aggregation is the set UNION — declared as
    ``aggregate_op="any"`` (psum-of-bools), so the mesh executor can
    complete it with the native collective — and the apply step is the
    server retrain on the union.  Masks (rather than point copies) keep a
    point from being duplicated when it is both local to a node and a
    global SV — duplication would split dual weight and inflate the SV
    count.  Because every node's training set overlaps the shared global
    SV pool, the strategy declares ``replicate_data``: under the mesh
    executor each shard holds the full dataset and trains only its own
    nodes (reconstructed from ``node_shard_index``).  The
    byte-accounting hooks charge only the actual SV points pushed and
    broadcast — the algorithm's semantic compression, which a generic
    wire codec cannot know about.
    """

    aggregate_op = "any"
    replicate_data = True

    def __init__(self, *, C: float = 1.0, kernel=linear_kernel, iters: int = 500):
        self.C = C
        self.kernel = kernel
        self.iters = iters

    def _pooled(self, data):
        Xs, ys = data
        Knodes, Nk, n = Xs.shape
        return Xs.reshape(Knodes * Nk, n), ys.reshape(Knodes * Nk)

    def init_theta(self, data):
        Xs, _ = data
        return jnp.zeros((Xs.shape[0] * Xs.shape[1],), dtype=bool)

    def init_state(self, theta, data):
        X, _ = self._pooled(data)
        return (jnp.zeros((X.shape[0],)), theta)  # (server α, pushed union)

    def _train(self, data, mask):
        X, y = self._pooled(data)
        return dual_svm(
            X, y, C=self.C, kernel=self.kernel, iters=self.iters, mask=mask
        )

    def local_updates(self, theta, state, data, batch):
        Xs, _ = data
        Knodes, Nk, _ = Xs.shape
        node_of = jnp.repeat(jnp.arange(Knodes), Nk)
        # data is replicated across mesh shards; each shard trains only
        # its own contiguous node slice (all K nodes locally)
        K_local = Knodes // _exec.num_node_shards()
        ks = _exec.node_shard_index() * K_local + jnp.arange(K_local)
        node_masks = jax.vmap(
            lambda k: ((node_of == k) | theta).astype(jnp.float32)
        )(ks)
        models = jax.vmap(lambda m: self._train(data, m))(node_masks)
        return models.sv_mask, state

    def apply_update(self, theta, pushed, state, data):
        model = self._train(data, pushed.astype(jnp.float32))
        return model.sv_mask, (model.alpha, pushed)

    def round_metric(self, theta, state, data):
        return theta  # trajectory = the global SV mask per round

    def _point_bytes(self, data, count):
        Xs, _ = data
        n = Xs.shape[-1]
        return count.astype(jnp.float32) * (n + 1) * 4.0  # f32 point + label

    def uplink_bytes(self, msgs_hat, data):
        # one union push per round: only the SV identities move.  The
        # union completes across mesh shards (identity locally) so every
        # placement reports the same global SV count.
        union = _exec.aggregate(msgs_hat, op="any")
        return self._point_bytes(data, jnp.sum(union))

    def downlink_bytes(self, theta, data):
        # broadcast of the new global SV set
        return self._point_bytes(data, jnp.sum(theta))

    def finalize(self, theta, state, data):
        X, y = self._pooled(data)
        alpha, _ = state
        return SVMModel(alpha=alpha, X=X, y=y, sv_mask=theta)

    def predict(self, theta, X):
        """Decision values f(x) for query points (``theta`` is the
        finalized ``SVMModel``); sign(f) is the class label."""
        return decision_function(theta, X, kernel=self.kernel)


def cascade_svm(
    Xs: jnp.ndarray,  # (K, Nk, n)
    ys: jnp.ndarray,  # (K, Nk)
    *,
    C: float = 1.0,
    kernel=linear_kernel,
    max_rounds: int = 5,
    iters: int = 500,
) -> CascadeResult:
    """Cascade SVM: only Support Vectors cross the network ([25]).

    Deprecation shim → ``api.fit(CascadeStrategy(...), transport="allreduce")``.
    "The procedure is repeated recursively until the SVs from one round to
    the other do not change" — the engine runs a fixed ``max_rounds`` scan
    (stable rounds are fixed points), and this shim truncates the reported
    rounds / SV counts / ledger at stabilization, exactly as the historical
    early-stopping loop did.
    """
    warnings.warn(
        "repro.ml.svm.cascade_svm is a deprecation shim; use "
        'repro.api.fit(CascadeStrategy(...), data, transport="allreduce")',
        DeprecationWarning,
        stacklevel=2,
    )
    n = Xs.shape[-1]
    N = Xs.shape[0] * Xs.shape[1]
    strategy = CascadeStrategy(C=C, kernel=kernel, iters=iters)
    res = fit(
        strategy, (Xs, ys), transport="allreduce", steps=max_rounds, tag="cascade"
    )
    masks = np.asarray(res.trajectory)  # (max_rounds, N) bool

    prev = np.zeros((N,), dtype=bool)
    rounds = max_rounds
    for r in range(max_rounds):
        if bool((masks[r] == prev).all()):
            rounds = r + 1
            break
        prev = masks[r]

    sv_counts = [int(masks[r].sum()) for r in range(rounds)]
    ledger = CommLedger()
    for r in range(rounds):
        up = int(res.metrics["uplink_bytes_per_round"][r])
        down = int(res.metrics["downlink_bytes_per_round"][r])
        ledger.uplink_bytes += up
        ledger.downlink_bytes += down
        ledger.events.append(("push", f"svs-r{r}", up))
        ledger.events.append(("pull", f"global-svs-r{r}", down))

    return CascadeResult(
        model=res.theta, rounds=rounds, ledger=ledger, sv_counts=sv_counts
    )


# ----------------------------------------------------------------------------
# Consensus SVM via ADMM ([22])
# ----------------------------------------------------------------------------

def smooth_hinge(m: jnp.ndarray, eps: float = 0.1) -> jnp.ndarray:
    """Huberized hinge — smooth surrogate so the local prox can use gradients."""
    return jnp.where(
        m >= 1.0,
        0.0,
        jnp.where(m <= 1.0 - eps, 1.0 - m - eps / 2.0, (1.0 - m) ** 2 / (2 * eps)),
    )


def _consensus_svm_prox_builder(inner_iters: int, inner_lr: float):
    """Smoothed-hinge local prox by inner gradient descent — the paper's
    "several proximity functions carried in parallel at each node"."""

    def build(data):
        Xs, ys = data
        Nk = Xs.shape[1]

        def node_grad(theta_rows):
            def one(theta, X, y):
                return jax.grad(
                    lambda t: jnp.sum(smooth_hinge(y * (X @ t)))
                )(theta)

            return jax.vmap(one)(theta_rows, Xs, ys)

        return gradient_local_prox(
            node_grad, inner_iters=inner_iters, lr=inner_lr / Nk
        )

    return build


def consensus_svm(
    Xs: jnp.ndarray,
    ys: jnp.ndarray,
    *,
    lam: float = 1e-2,
    rho: float = 1.0,
    iters: int = 100,
    inner_iters: int = 50,
    inner_lr: float = 0.5,
):
    """Primal consensus SVM: min Σ_k Σ_i hinge(y_i θᵀx_i) + (λ/2)‖z‖².

    Deprecation shim → ``api.fit(ProxStrategy(...),
    transport="admm_consensus", g="l2sq")``.
    """
    warnings.warn(
        "repro.ml.svm.consensus_svm is a deprecation shim; use "
        'repro.api.fit(ProxStrategy(...), data, transport="admm_consensus")',
        DeprecationWarning,
        stacklevel=2,
    )
    res = fit(
        ProxStrategy(_consensus_svm_prox_builder(inner_iters, inner_lr)),
        (Xs, ys),
        transport="admm_consensus",
        steps=iters,
        rho=rho,
        g="l2sq",
        g_lam=lam,
        tag="consensus-svm",
    )
    return res.metrics["admm"]


# ----------------------------------------------------------------------------
# The paper's own proposal: weighted dual consensus
# ----------------------------------------------------------------------------

def weighted_dual_consensus(
    Xs: jnp.ndarray,
    ys: jnp.ndarray,
    *,
    C: float = 1.0,
    kernel=linear_kernel,
    iters: int = 300,
    sparsity_lam: float = 0.05,
    node_weights: jnp.ndarray | None = None,
):
    """§3.2's sketched idea, made concrete.

    Each node solves its local dual but is penalized toward a *sparse*
    α (setting local SVs to zero "to satisfy consensus"), with per-node
    weights ∝ local example counts so nodes with more margin-relevant data
    are not drowned out.  Concretely: node k maximizes

        1ᵀα − ½αᵀQ_kα − (λ/w_k)‖α‖₁   s.t. 0 ≤ α ≤ C

    (an ℓ1-penalized dual; the ℓ1 prox is a shift since α ≥ 0) and the
    global decision function sums the per-node SV expansions.
    Returns per-node models and the joint decision function closure.
    """
    Knodes, Nk, _ = Xs.shape
    if node_weights is None:
        node_weights = jnp.full((Knodes,), float(Nk))
    w = node_weights / jnp.sum(node_weights)

    def solve_node(X, y, wk):
        K = kernel(X, X)
        Q = (y[:, None] * y[None, :]) * K
        L = jnp.maximum(jnp.linalg.norm(Q, ord=jnp.inf), 1e-6)
        shift = sparsity_lam / jnp.maximum(wk * Knodes, 1e-6)

        def step(alpha, _):
            g = 1.0 - Q @ alpha - shift  # ℓ1 prox on α ≥ 0 is a shift
            return jnp.clip(alpha + g / L, 0.0, C), None

        alpha, _ = jax.lax.scan(step, jnp.zeros(X.shape[0]), None, length=iters)
        return alpha

    alphas = jax.vmap(solve_node)(Xs, ys, w)  # (K, Nk)

    def decide(Xq):
        def one(X, y, alpha):
            return kernel(Xq, X) @ (alpha * y)

        return jnp.sum(jax.vmap(one)(Xs, ys, alphas * w[:, None] * Knodes), axis=0)

    return alphas, decide
