from repro.data.pipeline import (
    make_feature_shards,
    synthetic_lm_batches,
    synthetic_lm_batch,
)

__all__ = ["make_feature_shards", "synthetic_lm_batches", "synthetic_lm_batch"]
