"""Deterministic synthetic data pipelines.

Two kinds of data feed the framework:

* **LM token streams** for the assigned architectures — a seeded Markov-ish
  synthetic language (token t+1 depends on token t through a fixed affine
  map plus noise) so that models have actual structure to learn and loss
  curves are meaningful, while remaining fully offline and reproducible.
* **Feature shards** for the classical `ml/` algorithms — per-node
  (X_k, y_k) with controllable heterogeneity (the paper's homogeneous vs
  heterogeneous node-distribution distinction, §4.1).

Sharding: batches are generated per data-parallel group from a key folded
with the shard index — the same construction a multi-host input pipeline
would use (each host generates only its slice).
"""

from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


def synthetic_lm_batch(
    key: jax.Array,
    batch: int,
    seq: int,
    vocab: int,
    *,
    structure: int = 7,
) -> dict:
    """One (tokens, labels) LM batch with learnable bigram structure:
    ``tok_{t+1} = (structure * tok_t + noise_t) % vocab`` with sparse noise.
    """
    k1, k2, k3 = jax.random.split(key, 3)
    first = jax.random.randint(k1, (batch, 1), 0, vocab)
    noise = jax.random.randint(k2, (batch, seq), 0, vocab)
    keep = jax.random.bernoulli(k3, 0.1, (batch, seq))

    def step(tok, inputs):
        nz, kp = inputs
        nxt = jnp.where(kp, nz, (structure * tok + 1) % vocab)
        return nxt, nxt

    _, toks = jax.lax.scan(
        step, first[:, 0], (noise.T, keep.T)
    )
    tokens = toks.T  # (batch, seq)
    labels = jnp.roll(tokens, -1, axis=1)
    return {"tokens": tokens, "labels": labels}


def synthetic_lm_batches(
    seed: int,
    batch: int,
    seq: int,
    vocab: int,
    *,
    shard_index: int = 0,
    num_shards: int = 1,
) -> Iterator[dict]:
    """Infinite deterministic stream; each data shard draws disjoint keys."""
    assert batch % num_shards == 0
    local = batch // num_shards
    step = 0
    while True:
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(seed), step), shard_index
        )
        yield synthetic_lm_batch(key, local, seq, vocab)
        step += 1


def make_feature_shards(
    seed: int,
    num_nodes: int,
    per_node: int,
    dim: int,
    *,
    task: str = "regression",
    heterogeneity: float = 0.0,
    noise: float = 0.05,
):
    """Per-node (X, y) shards for the classical algorithms.

    ``heterogeneity`` shifts each node's feature distribution by a
    node-specific offset of that magnitude — 0.0 reproduces the paper's
    homogeneous case (each shard an i.i.d. sample of the same distribution),
    larger values the heterogeneous case that breaks naive aggregation.
    """
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=(dim,))
    Xs, ys = [], []
    for k in range(num_nodes):
        offset = heterogeneity * rng.normal(size=(dim,))
        X = rng.normal(size=(per_node, dim)) + offset
        if task == "regression":
            y = X @ w_true + noise * rng.normal(size=(per_node,))
        elif task == "classification":
            y = np.sign(X @ w_true + noise * rng.normal(size=(per_node,)))
            y[y == 0] = 1.0
        else:
            raise ValueError(task)
        Xs.append(X)
        ys.append(y)
    return (
        jnp.asarray(np.stack(Xs)),
        jnp.asarray(np.stack(ys)),
        jnp.asarray(w_true),
    )
