"""Pallas kernels: wall time per call (interpret mode on CPU — structural
check + relative comparison only; real perf numbers require a TPU) and
oracle agreement as the derived column."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) * 1e6 / reps


def run(rows):
    from repro.kernels.flash_attention import ops as fa, ref as fa_ref

    key = jax.random.key(0)
    q = jax.random.normal(key, (1, 256, 4, 64))
    k = jax.random.normal(key, (1, 256, 2, 64))
    v = jax.random.normal(key, (1, 256, 2, 64))
    us = _time(lambda a, b, c: fa.flash_attention(a, b, c, bq=128, bk=128), q, k, v)
    out = fa.flash_attention(q, k, v, bq=128, bk=128)
    exp = fa_ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
    ).transpose(0, 2, 1, 3)
    err = float(jnp.max(jnp.abs(out - exp)))
    rows.append(("kernels/flash_attention_256", us, f"max_err={err:.2e}"))

    from repro.kernels.decode_attention import ops as da, ref as da_ref

    q1 = jax.random.normal(key, (4, 8, 64))
    k1 = jax.random.normal(key, (4, 1024, 2, 64))
    v1 = jax.random.normal(key, (4, 1024, 2, 64))
    us = _time(lambda a, b, c: da.decode_attention(a, b, c, jnp.asarray(1000)), q1, k1, v1)
    err = float(
        jnp.max(
            jnp.abs(
                da.decode_attention(q1, k1, v1, jnp.asarray(1000))
                - da_ref.decode_attention_ref(q1, k1, v1, 1000)
            )
        )
    )
    rows.append(("kernels/decode_attention_1k", us, f"max_err={err:.2e}"))

    from repro.kernels.topk_compress import ops as tk, ref as tk_ref

    x = jax.random.normal(key, (65536,))
    us = _time(lambda a: tk.topk_sparsify(a, 1024), x)
    ok = bool(jnp.allclose(tk.topk_sparsify(x, 1024), tk_ref.topk_sparsify_ref(x, 1024)))
    rows.append(("kernels/topk_64k", us, f"exact={ok}"))

    from repro.kernels.pdist_argmin import ops as pd, ref as pd_ref

    X = jax.random.normal(key, (4096, 16))
    C = jax.random.normal(key, (64, 16))
    us = _time(lambda a, b: pd.pdist_argmin(a, b, metric="l2"), X, C)
    idx, _ = pd.pdist_argmin(X, C, metric="l2")
    eidx, _ = pd_ref.pdist_argmin_ref(X, C, metric="l2")
    rows.append(
        ("kernels/pdist_argmin_4k", us, f"agree={float(jnp.mean((idx == eidx)*1.0)):.4f}")
    )
