"""§3.3 distributed GPs: PoE / gPoE / BCM / gBCM prediction quality vs the
exact GP as the number of experts grows (the paper's comparison axis),
plus far-from-data calibration (the overconfidence pathology)."""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.ml import gp


def run(rows):
    rng = np.random.default_rng(31)
    N = 128
    X = jnp.asarray(np.sort(rng.uniform(-4, 4, size=(N, 1)), axis=0))
    y = jnp.asarray(np.sin(2 * np.asarray(X)[:, 0]) + 0.05 * rng.normal(size=N))
    Xq = jnp.asarray(np.linspace(-3.5, 3.5, 24)[:, None])
    truth = jnp.sin(2 * Xq[:, 0])

    hyp = gp.fit_hypers(X, y, steps=150)
    mu_full, _ = gp.gp_posterior(hyp, X, y, Xq)
    rmse_full = float(jnp.sqrt(jnp.mean((mu_full - truth) ** 2)))
    rows.append(("gp_experts/exact", 0.0, f"rmse={rmse_full:.4f}"))

    pv = gp.prior_variance(hyp, Xq)
    far = jnp.asarray([[50.0]])
    pv_far = float(gp.prior_variance(hyp, far)[0])

    # sparse GP [66]/[23]: accuracy vs exact, O(M²) wire per node
    Z = jnp.asarray(np.linspace(-3.5, 3.5, 16)[:, None])
    t0 = time.perf_counter()
    mu_s, _, wire = gp.distributed_sgpr(
        hyp, Z, X.reshape(4, N // 4, 1), y.reshape(4, N // 4), Xq
    )
    dt = (time.perf_counter() - t0) * 1e6
    rmse_s = float(jnp.sqrt(jnp.mean((mu_s - truth) ** 2)))
    rows.append(
        ("gp_experts/sgpr_distributed_M16", dt,
         f"rmse={rmse_s:.4f};wire_per_node={wire}")
    )

    for K in (2, 4, 8):
        Xs = X.reshape(K, N // K, 1)
        ys = y.reshape(K, N // K)
        t0 = time.perf_counter()
        preds = gp.expert_predictions(hyp, Xs, ys, Xq)
        dt = (time.perf_counter() - t0) * 1e6
        preds_far = gp.expert_predictions(hyp, Xs, ys, far)
        for name, (mu, var), (_, var_far) in [
            ("poe", gp.poe(preds), gp.poe(preds_far)),
            ("gpoe", gp.gpoe(preds), gp.gpoe(preds_far)),
            ("bcm", gp.bcm(preds, pv), gp.bcm(preds_far, jnp.asarray([pv_far]))),
            ("gbcm", gp.gbcm(preds, pv), gp.gbcm(preds_far, jnp.asarray([pv_far]))),
        ]:
            rmse = float(jnp.sqrt(jnp.mean((mu - truth) ** 2)))
            calib = float(var_far[0]) / pv_far  # →1.0 = falls back to prior
            rows.append(
                (f"gp_experts/{name}_K{K}", dt, f"rmse={rmse:.4f};far_var_ratio={calib:.3f}")
            )
