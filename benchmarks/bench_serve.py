"""Serving throughput: batch-bucket size sweep × placement (local vs mesh).

Drives a trained linear-GD model through ``ServeEngine``/``MicroBatcher``
at each batch bucket and measures steady-state requests/s after warmup
(compile excluded), plus per-request wire bytes from the inference
ledger.  The bucket sweep is the batcher's core trade: larger buckets
amortize dispatch, smaller ones bound padding waste and latency.  Writes
``BENCH_serve.json`` next to the repo root for the perf trajectory; also
pluggable into ``benchmarks.run``.

Run:
  PYTHONPATH=src python -m benchmarks.bench_serve
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.bench_serve
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.ml.linear import lsq_loss
from repro.serve import MicroBatcher, ServeEngine, ServeMetrics
from repro.telemetry import RunReport, Tracer

K, NK, N = 8, 64, 256
BUCKETS = (1, 4, 16, 64)
REQUESTS = 256


def _trained():
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(K, NK, N)))
    w = jnp.asarray(rng.normal(size=(N,)))
    y = jnp.einsum("kni,i->kn", X, w)
    strategy = api.GradientDescent(lsq_loss, lr=0.05)
    res = api.fit(strategy, (X, y), transport="allreduce", steps=100)
    return strategy, res


def _throughput(engine, bucket: int, queries: np.ndarray) -> float:
    batcher = MicroBatcher(engine, max_batch=bucket, tracer=engine.tracer)
    for q in queries[:bucket]:  # warmup: compile this bucket shape
        batcher.submit(q)
    batcher.flush()
    engine.metrics = ServeMetrics()  # drop warmup/compile from the stats
    t0 = time.perf_counter()
    tickets = [batcher.submit(q) for q in queries]
    batcher.flush()
    for t in tickets:
        t.result()
    return len(queries) / (time.perf_counter() - t0)


def run(rows):
    strategy, res = _trained()
    rng = np.random.default_rng(1)
    queries = rng.normal(size=(REQUESTS, N)).astype(np.float32)

    placements = {"local": None}
    if jax.device_count() > 1:
        placements["mesh"] = jax.make_mesh((jax.device_count(),), ("data",))

    results = {
        "workload": {"n_features": N, "requests": REQUESTS},
        "num_devices": jax.device_count(),
        "placements": {},
    }
    for pname, mesh in placements.items():
        per_bucket = {}
        for bucket in BUCKETS:
            engine = ServeEngine.from_fit(res, strategy, mesh=mesh)
            rps = _throughput(engine, bucket, queries)
            stats = engine.stats()
            per_bucket[bucket] = {
                "requests_per_s": rps,
                "p50_latency_ms": stats["p50_latency_ms"],
                "p99_latency_ms": stats["p99_latency_ms"],
                "request_bytes": stats["request_bytes"],
                "response_bytes": stats["response_bytes"],
            }
            rows.append(
                (f"serve_{pname}_b{bucket}", 1e6 / rps, f"{rps:.0f}rps")
            )
        results["placements"][pname] = per_bucket

    best = max(
        (b["requests_per_s"], k)
        for k, b in results["placements"]["local"].items()
    )
    results["derived"] = {
        "best_local_bucket": best[1],
        "bucket_speedup_vs_b1": best[0]
        / results["placements"]["local"][BUCKETS[0]]["requests_per_s"],
    }

    # one traced serving pass at the best bucket → RunReport markdown in
    # the sidecar (queue waits, predict spans, latency percentiles, pad
    # fraction alongside the raw throughput numbers)
    tracer = Tracer()
    engine = ServeEngine.from_fit(res, strategy, tracer=tracer)
    _throughput(engine, int(best[1]), queries)
    results["run_report_md"] = RunReport.from_serve(engine).to_markdown()
    out = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "BENCH_serve.json"))
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out}")
    return results


if __name__ == "__main__":
    rows: list = []
    res = run(rows)
    for r in rows:
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
    print(json.dumps(res["derived"], indent=2))
