"""Serving throughput: bucket sweep (classical) + continuous vs bucketed LM.

Two workloads:

* **Bucket sweep** — a trained linear-GD model through
  ``ServeEngine``/``MicroBatcher`` at each batch bucket, measuring
  steady-state requests/s after warmup (compile excluded) plus
  per-request wire bytes.  Larger buckets amortize dispatch, smaller
  ones bound padding waste and latency.
* **Poisson LM trace** — a tiny LM served twice over the SAME
  Poisson-arrival request trace (mixed generation lengths): the
  fixed-bucket baseline (every request in a bucket decodes
  ``GEN_MAX`` tokens — early finishers stall their batch) vs the
  continuous-batching ``ContinuousLMEngine`` (slots retire and refill
  independently over the paged KV cache).  Reported as *useful*
  tokens/s — requested tokens over makespan — so the baseline pays for
  the tokens nobody asked for.  The ratio is the PR's headline number
  and is bounded in ``tools/perf_smoke.py``.

Writes ``BENCH_serve.json`` next to the repo root for the perf
trajectory; also pluggable into ``benchmarks.run``.

Run:
  PYTHONPATH=src python -m benchmarks.bench_serve
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.bench_serve
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.ml.linear import lsq_loss
from repro.serve import ContinuousLMEngine, MicroBatcher, ServeEngine, ServeMetrics
from repro.telemetry import RunReport, Tracer

K, NK, N = 8, 64, 256
BUCKETS = (1, 4, 16, 64)
REQUESTS = 256

# Poisson LM trace
LM_REQUESTS = 24
LM_PROMPT = 16
LM_GEN_MAX = 16
LM_SLOTS = 4
LM_ARRIVAL_MEAN_S = 0.002


def _trained():
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(K, NK, N)))
    w = jnp.asarray(rng.normal(size=(N,)))
    y = jnp.einsum("kni,i->kn", X, w)
    strategy = api.GradientDescent(lsq_loss, lr=0.05)
    res = api.fit(strategy, (X, y), transport="allreduce", steps=100)
    return strategy, res


def _throughput(engine, bucket: int, queries: np.ndarray) -> float:
    batcher = MicroBatcher(engine, max_batch=bucket, tracer=engine.tracer)
    for q in queries[:bucket]:  # warmup: compile this bucket shape
        batcher.submit(q)
    batcher.flush()
    engine.metrics = ServeMetrics()  # drop warmup/compile from the stats
    t0 = time.perf_counter()
    tickets = [batcher.submit(q) for q in queries]
    batcher.flush()
    for t in tickets:
        t.result()
    return len(queries) / (time.perf_counter() - t0)


def _lm_setup():
    from repro.models import transformer as tf
    from repro.models.config import ModelConfig

    cfg = ModelConfig(
        name="bench-lm", vocab_size=512, d_model=64, num_layers=4,
        num_heads=8, num_kv_heads=4, head_dim=8, d_ff=256,
        compute_dtype="float32", param_dtype="float32",
    )
    params = tf.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(7)
    prompts = rng.integers(
        0, cfg.vocab_size, size=(LM_REQUESTS, LM_PROMPT)
    ).astype(np.int32)
    max_new = rng.integers(4, LM_GEN_MAX + 1, size=LM_REQUESTS)
    arrivals = np.cumsum(rng.exponential(LM_ARRIVAL_MEAN_S, size=LM_REQUESTS))
    return cfg, params, prompts, max_new, arrivals


def _lm_continuous(cfg, params, prompts, max_new, arrivals, *, tracer=None):
    """Replay the trace through the continuous engine; returns
    (useful tokens/s, engine)."""
    engine = ContinuousLMEngine(
        cfg, params, n_slots=LM_SLOTS, page_size=8,
        max_seq=LM_PROMPT + LM_GEN_MAX, tracer=tracer, tag="serve/bench-lm",
    )
    engine.submit(prompts[0], max_new=2).result()  # compile outside the clock
    t0 = time.perf_counter()
    i, tickets = 0, []
    while i < len(prompts) or engine.sched.n_active or engine.sched.backlog:
        now = time.perf_counter() - t0
        while i < len(prompts) and arrivals[i] <= now:
            tickets.append(engine.submit(prompts[i], max_new=int(max_new[i])))
            i += 1
        if engine.step() == 0 and i < len(prompts):
            time.sleep(arrivals[i] - now if arrivals[i] > now else 0)
    for t in tickets:
        t.result()
    makespan = time.perf_counter() - t0
    return int(max_new.sum()) / makespan, engine


def _lm_bucketed(cfg, params, prompts, max_new, arrivals):
    """Replay the same trace through the fixed-bucket baseline: every
    request in a flushed bucket decodes LM_GEN_MAX tokens regardless of
    how few it asked for."""
    from repro.api.strategy import OptimizerStrategy
    from repro.launch.serve import lm_predict_fn

    strategy = OptimizerStrategy(
        None, None, predict_fn=lm_predict_fn(cfg, gen=LM_GEN_MAX)
    )
    engine = ServeEngine(strategy, params, tag="serve/bench-lm")
    batcher = MicroBatcher(engine, max_batch=LM_SLOTS, timeout_s=0.003)
    for p in prompts[:LM_SLOTS]:  # compile the full bucket outside the clock
        batcher.submit(p)
    batcher.flush()
    t0 = time.perf_counter()
    i, tickets = 0, []
    while i < len(prompts) or batcher.pending() or not all(
        t.done for t in tickets
    ):
        now = time.perf_counter() - t0
        while i < len(prompts) and arrivals[i] <= now:
            tickets.append(batcher.submit(prompts[i]))
            i += 1
        if not batcher.poll():
            if i < len(prompts):
                time.sleep(arrivals[i] - now if arrivals[i] > now else 0)
            else:
                time.sleep(batcher.timeout_s / 4)  # tail: wait out the flush
    for t in tickets:
        t.result()
    makespan = time.perf_counter() - t0
    return int(max_new.sum()) / makespan


def run(rows):
    strategy, res = _trained()
    rng = np.random.default_rng(1)
    queries = rng.normal(size=(REQUESTS, N)).astype(np.float32)

    placements = {"local": None}
    if jax.device_count() > 1:
        placements["mesh"] = jax.make_mesh((jax.device_count(),), ("data",))

    results = {
        "workload": {"n_features": N, "requests": REQUESTS},
        "num_devices": jax.device_count(),
        "placements": {},
    }
    for pname, mesh in placements.items():
        per_bucket = {}
        for bucket in BUCKETS:
            engine = ServeEngine.from_fit(res, strategy, mesh=mesh)
            rps = _throughput(engine, bucket, queries)
            stats = engine.stats()
            per_bucket[bucket] = {
                "requests_per_s": rps,
                "p50_latency_ms": stats["p50_latency_ms"],
                "p99_latency_ms": stats["p99_latency_ms"],
                "request_bytes": stats["request_bytes"],
                "response_bytes": stats["response_bytes"],
            }
            rows.append(
                (f"serve_{pname}_b{bucket}", 1e6 / rps, f"{rps:.0f}rps")
            )
        results["placements"][pname] = per_bucket

    best = max(
        (b["requests_per_s"], k)
        for k, b in results["placements"]["local"].items()
    )

    # -- Poisson LM trace: continuous vs fixed-bucket, same trace ------------
    cfg, params, prompts, max_new, arrivals = _lm_setup()
    bucketed_tps = _lm_bucketed(cfg, params, prompts, max_new, arrivals)
    tracer = Tracer()
    cont_tps, cont_engine = _lm_continuous(
        cfg, params, prompts, max_new, arrivals, tracer=tracer
    )
    stats = cont_engine.stats()
    results["lm_poisson"] = {
        "requests": LM_REQUESTS,
        "prompt_len": LM_PROMPT,
        "gen_max": LM_GEN_MAX,
        "slots": LM_SLOTS,
        "useful_tokens": int(max_new.sum()),
        "continuous_tokens_per_s": cont_tps,
        "bucketed_tokens_per_s": bucketed_tps,
        "slot_utilization": stats["slot_utilization"],
        "p50_token_ms": stats["p50_token_ms"],
        "p99_token_ms": stats["p99_token_ms"],
        "p50_latency_ms": stats["p50_latency_ms"],
        "p99_latency_ms": stats["p99_latency_ms"],
        "kernel_hits": dict(cont_engine.kernel_hits),
    }
    rows.append(("serve_lm_bucketed", 1e6 / bucketed_tps,
                 f"{bucketed_tps:.0f}tok/s"))
    rows.append(("serve_lm_continuous", 1e6 / cont_tps,
                 f"{cont_tps:.0f}tok/s"))

    results["derived"] = {
        "best_local_bucket": best[1],
        "bucket_speedup_vs_b1": best[0]
        / results["placements"]["local"][BUCKETS[0]]["requests_per_s"],
        "continuous_over_bucketed_tokens_per_s": cont_tps / bucketed_tps,
    }

    # RunReport markdown sidecars: one traced pass of the classical sweep
    # at its best bucket, plus the continuous LM engine's report (token
    # throughput, slot utilization, decode kernel hits, spans)
    gd_tracer = Tracer()
    engine = ServeEngine.from_fit(res, strategy, tracer=gd_tracer)
    _throughput(engine, int(best[1]), queries)
    results["run_report_md"] = (
        RunReport.from_serve(engine).to_markdown()
        + "\n"
        + RunReport.from_serve(cont_engine).to_markdown()
    )
    out = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "BENCH_serve.json"))
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out}")
    return results


if __name__ == "__main__":
    rows: list = []
    res = run(rows)
    for r in rows:
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
    print(json.dumps(res["derived"], indent=2))
