"""Benchmark harness — one module per paper claim/table (DESIGN.md §6).

Prints ``name,us_per_call,derived`` CSV.  Run:
  PYTHONPATH=src python -m benchmarks.run [--only substring]
"""

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="run benches whose name contains this")
    args = ap.parse_args()

    from benchmarks import (
        bench_admm,
        bench_async_vs_sync,
        bench_cascade_svm,
        bench_clustering,
        bench_compression,
        bench_fit_executors,
        bench_gp_experts,
        bench_kernels,
        bench_multipod,
        bench_serve,
        bench_staleness,
    )

    modules = {
        "async_vs_sync": bench_async_vs_sync,
        "staleness": bench_staleness,
        "admm": bench_admm,
        "compression": bench_compression,
        "fit_executors": bench_fit_executors,
        "multipod": bench_multipod,
        "serve": bench_serve,
        "cascade_svm": bench_cascade_svm,
        "gp_experts": bench_gp_experts,
        "clustering": bench_clustering,
        "kernels": bench_kernels,
    }

    rows: list = []
    print("name,us_per_call,derived")
    for name, mod in modules.items():
        if args.only and args.only not in name:
            continue
        try:
            start = len(rows)
            mod.run(rows)
            for r in rows[start:]:
                print(f"{r[0]},{r[1]:.1f},{r[2]}")
                sys.stdout.flush()
        except Exception:  # noqa: BLE001 — print and continue
            print(f"{name},ERROR,{traceback.format_exc().splitlines()[-1]}")


if __name__ == "__main__":
    main()
