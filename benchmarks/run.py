"""Benchmark harness — one module per paper claim/table (DESIGN.md §6).

Prints ``name,us_per_call,derived`` CSV.  Each bench module imports
independently: an import failure (missing optional dep, broken
accelerator stack) reports a ``SKIP(import)`` row and the rest of the
suite still runs.

The executor/multipod/serve benches additionally embed a
``run_report_md`` block (``telemetry.report.RunReport`` rendered to
markdown — per-phase device times, per-hop bytes, cache state, latency
percentiles) in their ``BENCH_*.json`` sidecars, so the checked-in perf
trajectory carries the phase decomposition, not just wall times.

Run:
  PYTHONPATH=src python -m benchmarks.run [--only substring]
"""

import argparse
import importlib
import sys
import traceback

#: name → module path; imported lazily one at a time so a single broken
#: import cannot take down the whole harness
MODULES = {
    "async_vs_sync": "benchmarks.bench_async_vs_sync",
    "staleness": "benchmarks.bench_staleness",
    "admm": "benchmarks.bench_admm",
    "compression": "benchmarks.bench_compression",
    "fit_executors": "benchmarks.bench_fit_executors",
    "multipod": "benchmarks.bench_multipod",
    "faults": "benchmarks.bench_faults",
    "serve": "benchmarks.bench_serve",
    "cascade_svm": "benchmarks.bench_cascade_svm",
    "gp_experts": "benchmarks.bench_gp_experts",
    "clustering": "benchmarks.bench_clustering",
    "kernels": "benchmarks.bench_kernels",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="run benches whose name contains this")
    args = ap.parse_args()

    rows: list = []
    print("name,us_per_call,derived")
    for name, modpath in MODULES.items():
        if args.only and args.only not in name:
            continue
        try:
            mod = importlib.import_module(modpath)
        except Exception:  # noqa: BLE001 — report the skip, keep going
            err = traceback.format_exc().splitlines()[-1]
            print(f"{name},SKIP(import),{err}")
            sys.stdout.flush()
            continue
        try:
            start = len(rows)
            mod.run(rows)
            for r in rows[start:]:
                print(f"{r[0]},{r[1]:.1f},{r[2]}")
                sys.stdout.flush()
        except Exception:  # noqa: BLE001 — print and continue
            print(f"{name},ERROR,{traceback.format_exc().splitlines()[-1]}")


if __name__ == "__main__":
    main()
