"""§3.1 consensus ADMM: distributed LASSO quality + communication cost vs
the centralized solver."""

from __future__ import annotations

import time

import jax.numpy as jnp

from repro.data import make_feature_shards
from repro.ml import linear


def run(rows):
    K, Nk, n = 8, 50, 20
    Xs, ys, _ = make_feature_shards(2, K, Nk, n, noise=0.05)
    Xall, yall = Xs.reshape(-1, n), ys.reshape(-1)
    lam = 1.0

    t0 = time.perf_counter()
    ref = linear.ista_lasso(Xall, yall, lam, iters=4000)
    ista_us = (time.perf_counter() - t0) * 1e6

    obj_ref = float(linear.centralized_lasso_objective(ref, Xall, yall, lam))
    rows.append(("admm_lasso/ista_centralized", ista_us, f"{obj_ref:.4f}"))

    for iters in (25, 50, 100, 200):
        t0 = time.perf_counter()
        res = linear.admm_lasso(Xs, ys, lam=lam, iters=iters)
        dt = (time.perf_counter() - t0) * 1e6
        obj = float(linear.centralized_lasso_objective(res.z, Xall, yall, lam))
        gap = obj - obj_ref
        # comm: 2 Allreduce per iteration of an n-vector to/from K nodes
        comm = iters * 2 * 2 * K * n * 4
        rows.append(
            (f"admm_lasso/iters{iters}", dt, f"gap={gap:.5f};comm_bytes={comm}")
        )

    # distributed L-BFGS (one Allreduce/iter) vs GD on logistic
    Xs2, ys2, _ = make_feature_shards(3, K, Nk, n, task="classification")
    lb = linear.distributed_lbfgs(Xs2, ys2, steps=40)
    gd = linear.distributed_gd(
        Xs2, ys2, loss=linear.logistic_loss, steps=40, lr=0.5
    )
    rows.append(
        ("lbfgs_vs_gd/lbfgs40", float(lb.ledger.total_bytes), f"{float(lb.losses[-1]):.4f}")
    )
    rows.append(
        ("lbfgs_vs_gd/gd40", float(gd.ledger.total_bytes), f"{float(gd.losses[-1]):.4f}")
    )

    # §3.4: distributed MPLE for a chain Gaussian MRF ([38])
    import jax

    from repro.ml import graphical

    d = 6
    Theta = jnp.eye(d) * 1.5
    for i in range(d - 1):
        Theta = Theta.at[i, i + 1].set(0.5).at[i + 1, i].set(0.5)
    Xg = graphical.sample_gmrf(jax.random.key(0), Theta, 2000)
    t0 = time.perf_counter()
    Th_d, _ = graphical.mple_consensus(Xg.reshape(4, 500, d), iters=50)
    dt = (time.perf_counter() - t0) * 1e6
    f1 = float(graphical.support_f1(Th_d, Theta))
    rows.append(("mple_consensus/chain_gmrf", dt, f"support_f1={f1:.3f}"))
