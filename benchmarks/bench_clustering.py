"""§4 clustering: k-means (ℓ1/ℓ2/ℓ∞) vs k-windows, the paper's qualitative
claims quantified:

* k-windows precision is high, recall limited (§4.2);
* k-windows degrades in high dimension ("not very effective in
  high-dimensional spaces");
* the naive distributed merge [60] over-merges close clusters;
* sufficient-stats distributed k-means is exact vs centralized.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ml import clustering, kwindows


def _blobs(rng, dim, sep, n_per=60, K=3):
    # deterministic well-separated centers (random draws can collide and
    # make precision meaningless); heterogeneity comes from the points
    centers = np.zeros((K, dim))
    for k in range(K):
        centers[k, k % dim] = sep * (k + 1) * (-1) ** k
    X = np.concatenate([rng.normal(size=(n_per, dim)) + c for c in centers])
    labels = np.repeat(np.arange(K), n_per)
    return jnp.asarray(X), centers, labels


def _precision_recall(assign, labels, n_clusters):
    correct = 0
    captured = 0
    for w in range(n_clusters):
        pts = np.asarray(assign) == w
        if pts.sum() == 0:
            continue
        correct += np.bincount(labels[pts]).max()
        captured += pts.sum()
    precision = correct / max(captured, 1)
    recall = captured / len(labels)
    return precision, recall


def run(rows):
    rng = np.random.default_rng(41)

    # --- metric comparison on well-separated 2-D blobs
    X, centers, labels = _blobs(rng, 2, 4.0)
    C0 = clustering.kmeans_pp_init(jax.random.key(0), X, 3)
    for metric in ("l1", "l2", "linf"):
        t0 = time.perf_counter()
        res = clustering.kmeans(X, C0, num_clusters=3, metric=metric, iters=30)
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((f"clustering/kmeans_{metric}", dt, f"inertia={float(res.inertia):.1f}"))

    # --- sufficient-stats distributed == centralized
    perm = np.random.default_rng(1).permutation(X.shape[0])
    Xs = jnp.asarray(np.asarray(X)[perm]).reshape(3, 60, 2)
    res_d = clustering.distributed_kmeans(Xs, C0, num_clusters=3, iters=25)
    res_c = clustering.kmeans(
        jnp.asarray(np.asarray(X)[perm]), C0, num_clusters=3, metric="l2sq", iters=25
    )
    gap = abs(float(res_d.inertia) - float(res_c.inertia))
    rows.append(("clustering/distributed_vs_central_gap", 0.0, f"{gap:.6f}"))

    # --- k-windows: precision/recall at 2-D and high-D (paper's claim)
    for dim in (2, 20):
        X, centers, labels = _blobs(rng, dim, 3.0 if dim == 2 else 1.2)
        t0 = time.perf_counter()
        win = kwindows.kwindows(
            jax.random.key(2), X, num_windows=9, r=1.2 if dim == 2 else 2.0
        )
        dt = (time.perf_counter() - t0) * 1e6
        assign = kwindows.assign_points(X, win)
        p, r = _precision_recall(assign, labels, win.centers.shape[0])
        rows.append(
            (
                f"clustering/kwindows_d{dim}",
                dt,
                f"precision={p:.3f};recall={r:.3f};alive={int(jnp.sum(win.alive))}",
            )
        )

    # --- naive distributed k-windows over-merges close clusters
    X, centers, labels = _blobs(rng, 2, 1.0)  # closely-spaced blobs
    Xs = X.reshape(3, 60, 2)
    win_c = kwindows.kwindows(jax.random.key(3), X, num_windows=6, r=1.2)
    win_d = kwindows.distributed_kwindows(jax.random.key(3), Xs, num_windows=6, r=1.2)
    rows.append(
        (
            "clustering/kwindows_naive_distributed",
            0.0,
            f"central_alive={int(jnp.sum(win_c.alive))};"
            f"distributed_alive={int(jnp.sum(win_d.alive))}",
        )
    )

    # --- radius-T [27] + merge
    X, centers, labels = _blobs(rng, 2, 4.0)
    t0 = time.perf_counter()
    C, counts, mask = clustering.radius_t_clustering(X, T=2.5, max_clusters=20)
    C, counts, mask = clustering.merge_centroids(C, counts, mask, T=2.5)
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(
        ("clustering/radius_t", dt, f"clusters={int(jnp.sum(mask))}")
    )
