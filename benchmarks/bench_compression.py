"""Low-communication-overhead push (§1/§5 motif): wire bytes vs final loss
for top-k / rand-k / int8 on a reduced LM, with error feedback."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.compression import (
    ef_compress,
    ef_init,
    int8_compress,
    randk_compress,
    raw_bytes,
    topk_compress,
)
from repro.data import synthetic_lm_batches
from repro.models import transformer as tf


def run(rows):
    cfg = get_config("tinyllama-1.1b").reduced().replace(vocab_size=256)
    params0 = tf.init_params(jax.random.key(0), cfg)
    grad_fn = jax.jit(jax.value_and_grad(lambda p, b: tf.loss_fn(p, cfg, b)[0]))
    steps, lr = 40, 0.05
    full_bytes = raw_bytes(params0) * steps

    compressors = {
        "none": None,
        "topk_10pct": lambda t: topk_compress(t, 0.10),
        "topk_1pct": lambda t: topk_compress(t, 0.01),
        "int8": int8_compress,
    }
    for name, comp in compressors.items():
        params = params0
        ef = ef_init(params0)
        data = synthetic_lm_batches(4, 4, 32, cfg.vocab_size)
        wire = 0.0
        last = 0.0
        t0 = time.perf_counter()
        for i in range(steps):
            l, g = grad_fn(params, next(data))
            if comp is not None:
                ef, c = ef_compress(ef, g, comp)
                g = c.tree
                wire += float(c.wire_bytes)
            else:
                wire += raw_bytes(params)
            params = jax.tree.map(lambda t, gi: t - lr * gi, params, g)
            last = float(l)
        dt = (time.perf_counter() - t0) * 1e6 / steps
        rows.append(
            (
                f"compression/{name}",
                dt,
                f"loss={last:.4f};wire_ratio={wire/full_bytes:.4f}",
            )
        )

    # rand-k needs a key per step — separate loop.  The 1/p rescale gives
    # unbiased but 10x-variance gradients: the stable step size is lr·p.
    params = params0
    ef = ef_init(params0)
    data = synthetic_lm_batches(4, 4, 32, cfg.vocab_size)
    wire, last = 0.0, 0.0
    lr_rk = lr * 0.10
    t0 = time.perf_counter()
    for i in range(steps):
        l, g = grad_fn(params, next(data))
        ef, c = ef_compress(
            ef, g, lambda t: randk_compress(jax.random.key(i), t, 0.10)
        )
        wire += float(c.wire_bytes)
        params = jax.tree.map(lambda t, gi: t - lr_rk * gi, params, c.tree)
        last = float(l)
    dt = (time.perf_counter() - t0) * 1e6 / steps
    rows.append(
        ("compression/randk_10pct", dt, f"loss={last:.4f};wire_ratio={wire/full_bytes:.4f}")
    )
