"""Sequential dry-run sweep over all (arch × shape × mesh) combinations.

Single-pod runs include cost probes (roofline inputs); multi-pod runs are
lower+compile proofs only.  Existing JSONs are skipped so the sweep is
resumable.  Run:  PYTHONPATH=src python benchmarks/sweep_dryrun.py
"""

import json
import os
import subprocess
import sys
import time

ARCHS = [
    "xlstm-125m",
    "whisper-base",
    "tinyllama-1.1b",
    "qwen2-1.5b",
    "qwen2-vl-2b",
    "olmoe-1b-7b",
    "minicpm3-4b",
    "deepseek-67b",
    "jamba-1.5-large-398b",
    "deepseek-v3-671b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
OUT = "experiments/dryrun"


def path_for(arch, shape, multipod):
    mesh = "2x16x16" if multipod else "16x16"
    return os.path.join(OUT, f"{arch}__{shape}__{mesh}.json")


def main():
    os.makedirs(OUT, exist_ok=True)
    jobs = []
    for multipod in (False, True):
        for arch in ARCHS:
            for shape in SHAPES:
                jobs.append((arch, shape, multipod))
    t0 = time.time()
    for i, (arch, shape, multipod) in enumerate(jobs):
        p = path_for(arch, shape, multipod)
        if os.path.exists(p):
            try:
                st = json.load(open(p)).get("status")
            except Exception:
                st = None
            if st in ("ok", "skipped"):
                print(f"[{i+1}/{len(jobs)}] SKIP (done) {p}", flush=True)
                continue
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape, "--out", OUT,
        ]
        if multipod:
            cmd.append("--multipod")
        print(
            f"[{i+1}/{len(jobs)}] {arch} {shape} "
            f"{'2x16x16' if multipod else '16x16'} "
            f"(t={time.time()-t0:.0f}s)", flush=True,
        )
        r = subprocess.run(
            cmd, capture_output=True, text=True, timeout=3600,
            env=dict(os.environ, PYTHONPATH="src"),
        )
        if r.returncode != 0:
            print(f"  FAILED rc={r.returncode}: {r.stderr[-500:]}", flush=True)
            with open(p, "w") as f:
                json.dump(
                    {"arch": arch, "shape": shape,
                     "mesh": "2x16x16" if multipod else "16x16",
                     "status": "crash", "stderr": r.stderr[-2000:]}, f)
        else:
            try:
                st = json.load(open(p))
                print(
                    f"  -> {st['status']} compile={st.get('compile_s')}s "
                    f"probe={st.get('probe_s')}s "
                    f"mem={st.get('memory', {}).get('steady_state_bytes', 0)/2**30:.1f}GiB",
                    flush=True,
                )
            except Exception as e:
                print(f"  -> result unreadable: {e}", flush=True)
    print(f"sweep done in {time.time()-t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
