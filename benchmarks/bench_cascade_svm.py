"""§3.2 cascade SVM: accuracy, rounds-to-stability, and wire bytes vs
centralized training and vs shipping the raw data."""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.ml import svm


def run(rows):
    rng = np.random.default_rng(21)
    K, Nk, n = 8, 50, 4
    half = K * Nk // 2
    Xp = rng.normal(size=(half, n)) + 1.8
    Xm = rng.normal(size=(half, n)) - 1.8
    X = np.concatenate([Xp, Xm])
    y = np.concatenate([np.ones(half), -np.ones(half)])
    perm = rng.permutation(len(X))
    X, y = X[perm], y[perm]
    Xs = jnp.asarray(X.reshape(K, Nk, n))
    ys = jnp.asarray(y.reshape(K, Nk))
    Xj, yj = jnp.asarray(X), jnp.asarray(y)

    t0 = time.perf_counter()
    central = svm.dual_svm(Xj, yj, C=1.0)
    dt_c = (time.perf_counter() - t0) * 1e6
    acc_c = float(jnp.mean(jnp.sign(svm.decision_function(central, Xj)) == yj))
    rows.append(("cascade_svm/centralized", dt_c, f"acc={acc_c:.4f}"))

    t0 = time.perf_counter()
    cas = svm.cascade_svm(Xs, ys, C=1.0, max_rounds=6)
    dt = (time.perf_counter() - t0) * 1e6
    acc = float(jnp.mean(jnp.sign(svm.decision_function(cas.model, Xj)) == yj))
    raw = X.size * 4 + y.size * 4
    rows.append(
        (
            "cascade_svm/cascade",
            dt,
            f"acc={acc:.4f};rounds={cas.rounds};svs={cas.sv_counts[-1]};"
            f"wire_vs_raw={cas.ledger.total_bytes/raw:.4f}",
        )
    )

    t0 = time.perf_counter()
    cons = svm.consensus_svm(Xs, ys, iters=80)
    dt = (time.perf_counter() - t0) * 1e6
    acc2 = float(jnp.mean(jnp.sign(Xj @ cons.z) == yj))
    rows.append(("cascade_svm/consensus_admm", dt, f"acc={acc2:.4f}"))
