"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md tables.

  PYTHONPATH=src python benchmarks/aggregate_dryrun.py [--markdown]
"""

import argparse
import glob
import json
import os


def load(out="experiments/dryrun"):
    rows = []
    for f in sorted(glob.glob(os.path.join(out, "*.json"))):
        d = json.load(open(f))
        rows.append(d)
    return rows


def fmt_bytes(b):
    return f"{b/2**30:.1f}"


def dryrun_table(rows, mesh):
    hdr = (
        "| arch | shape | status | params | compile s | HBM/dev GiB | fits 16G |\n"
        "|---|---|---|---|---|---|---|"
    )
    lines = [hdr]
    for d in rows:
        if d.get("mesh") != mesh:
            continue
        if d["status"] == "skipped":
            lines.append(
                f"| {d['arch']} | {d['shape']} | SKIP ({d['reason'][:40]}...) | | | | |"
            )
            continue
        mem = d.get("memory", {}).get("steady_state_bytes", 0)
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['status']} | "
            f"{d.get('n_params', 0)/1e9:.2f}B | {d.get('compile_s', 0):.0f} | "
            f"{fmt_bytes(mem)} | {'Y' if mem <= 16 * 2**30 else 'N'} |"
        )
    return "\n".join(lines)


def roofline_table(rows):
    hdr = (
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS | useful | note |\n|---|---|---|---|---|---|---|---|---|"
    )
    lines = [hdr]
    for d in rows:
        if d.get("mesh") != "16x16" or d["status"] != "ok" or d.get("tag"):
            continue
        r = d["roofline"]
        lines.append(
            f"| {d['arch']} | {d['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | **{r['dominant']}** | "
            f"{r['model_flops']:.2e} | {r['useful_ratio']:.2f} | "
            f"{d['config'].get('remat','')}"
            f"{'/sw' + str(d['config']['sliding_window']) if d['config'].get('sliding_window') else ''} |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    rows = load(args.out)
    ok = sum(1 for d in rows if d["status"] == "ok")
    sk = sum(1 for d in rows if d["status"] == "skipped")
    print(f"## Dry-run summary: {ok} ok, {sk} skipped, {len(rows)-ok-sk} failed\n")
    print("### Single pod (16x16 = 256 chips)\n")
    print(dryrun_table(rows, "16x16"))
    print("\n### Multi-pod (2x16x16 = 512 chips)\n")
    print(dryrun_table(rows, "2x16x16"))
    print("\n## Roofline (single pod, probe-corrected)\n")
    print(roofline_table(rows))


if __name__ == "__main__":
    main()
