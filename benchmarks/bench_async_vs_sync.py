"""Paper §5's central claim: asynchronous central-server training converges
at the same rate as the synchronous (round-robin ≡ mini-batch) algorithm.

Benchmarked on (a) distributed logistic regression (the paper's running
example class) and (b) a reduced LM — loss after equal numbers of
contacts.  Both run through the unified ``repro.api.fit`` entry point;
the schedule/handoff variants are pure transport choices on one strategy.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import api
from repro.configs import get_config
from repro.core import schedules
from repro.data import make_feature_shards, synthetic_lm_batch
from repro.ml.linear import logistic_loss
from repro.models import transformer as tf


def logistic_case(rows):
    K, Nk, n = 8, 40, 10
    Xs, ys, w = make_feature_shards(0, K, Nk, n, task="classification")
    lr = 0.3

    def F(k, theta):
        g = jax.grad(logistic_loss)(theta, Xs[k], ys[k])
        return theta - lr * g

    def mean_loss(theta):
        return float(
            jnp.mean(jax.vmap(logistic_loss, in_axes=(None, 0, 0))(theta, Xs, ys))
        )

    strategy = api.FunctionStrategy(F, num_nodes=K)
    contacts = 200
    for name, sched, transport in [
        ("sync_round_robin", schedules.round_robin(K, contacts // K), "sequential_server"),
        ("stale_round_robin", schedules.round_robin(K, contacts // K), "stale_server"),
        ("async_uniform", schedules.asynchronous(jax.random.key(0), K, contacts), "sequential_server"),
        (
            "async_work_proportional",
            schedules.asynchronous(
                jax.random.key(0), K, contacts,
                probs=schedules.work_proportional_probs(jnp.arange(1, K + 1) * 10.0),
            ),
            "sequential_server",
        ),
    ]:
        t0 = time.perf_counter()
        res = api.fit(
            strategy, transport=transport, schedule=sched, theta0=jnp.zeros(n)
        )
        jax.block_until_ready(res.theta)
        dt = (time.perf_counter() - t0) * 1e6
        rows.append(
            ("async_vs_sync_logistic/" + name, dt / contacts, f"{mean_loss(res.theta):.4f}")
        )


def lm_case(rows):
    cfg = get_config("tinyllama-1.1b").reduced().replace(vocab_size=256)
    params = tf.init_params(jax.random.key(0), cfg)
    K = 4
    batches = [synthetic_lm_batch(jax.random.key(50 + k), 2, 32, 256) for k in range(K)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
    grad_fn = jax.jit(jax.grad(lambda p, b: tf.loss_fn(p, cfg, b)[0]))
    loss_fn = jax.jit(lambda p, b: tf.loss_fn(p, cfg, b)[0])
    lr = 0.05

    def F(k, theta):
        g = grad_fn(theta, jax.tree.map(lambda x: x[k], stacked))
        return jax.tree.map(lambda t, gi: t - lr * gi, theta, g)

    def mean_loss(theta):
        import numpy as np

        return float(np.mean([float(loss_fn(theta, b)) for b in batches]))

    strategy = api.FunctionStrategy(F, num_nodes=K)
    contacts = 24
    for name, sched in [
        ("sync", schedules.round_robin(K, contacts // K)),
        ("async", schedules.asynchronous(jax.random.key(7), K, contacts)),
    ]:
        t0 = time.perf_counter()
        res = api.fit(
            strategy, transport="sequential_server", schedule=sched, theta0=params
        )
        jax.block_until_ready(jax.tree.leaves(res.theta)[0])
        dt = (time.perf_counter() - t0) * 1e6
        rows.append(
            ("async_vs_sync_lm/" + name, dt / contacts, f"{mean_loss(res.theta):.4f}")
        )


def run(rows):
    logistic_case(rows)
    lm_case(rows)
