"""Client-fleet frontier: accuracy vs privacy noise vs bytes, faulted.

One ``mesh+sweep`` executable trains the dp-noise frontier under a
faulted fleet (seeded dropout + stragglers + a quorum gate): S values of
``dp_sigma`` share one compiled program, one fault-draw stream and one
8-fake-device mesh placement, yielding final loss and survivor-only
uplink bytes per scenario.  A second sweep walks ``dropout_p`` itself
(inverse-CDF coupled to the shared uniforms), and a traced faulted mesh
fit embeds its ``RunReport`` markdown in the sidecar.

Writes ``BENCH_faults.json`` next to the repo root; also pluggable into
``benchmarks.run`` (rows of ``name,us_per_call,derived``).

Run:
  PYTHONPATH=src python -m benchmarks.bench_faults
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

STEPS = 60

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
)
os.environ["JAX_PLATFORMS"] = "cpu"
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.api.executor import clear_program_cache, program_cache_stats
from repro.api.faults import FaultPlan
from repro.ml.linear import lsq_loss
from repro.telemetry import RunReport, Tracer

K, NK, N, STEPS = 8, 64, 256, %(steps)d

rng = np.random.default_rng(0)
Xs = jnp.asarray(rng.normal(size=(K, NK, N)))
w = jnp.asarray(rng.normal(size=(N,)))
y = jnp.einsum("kni,i->kn", Xs, w)
data = (Xs, y)
gd = lambda: api.GradientDescent(lsq_loss, lr=0.05)
plan = FaultPlan(seed=11, dropout_p=0.3, straggler=1, quorum=3)

def timed(fn):
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best

# the dp-sigma frontier: S noise levels, ONE faulted mesh+sweep
# executable — final loss vs survivor uplink bytes per scenario
sigmas = [0.0, 0.01, 0.05, 0.2, 1.0]
def dp_frontier():
    return api.fit(
        gd(), data, transport="allreduce", steps=STEPS,
        wire="dp:1.0,0.05", executor="mesh+sweep", faults=plan,
        sweep={"dp_sigma": jnp.asarray(sigmas)},
    )
clear_program_cache()
res = dp_frontier()
dt_frontier = timed(dp_frontier)
traj = np.asarray(res.trajectory)
ledgers = res.ledger if isinstance(res.ledger, list) else [res.ledger]
frontier = [
    {
        "dp_sigma": s,
        "final_loss": float(traj[i, -1]),
        "uplink_bytes": int(ledgers[i].uplink_bytes),
        "downlink_bytes": int(ledgers[i].downlink_bytes),
    }
    for i, s in enumerate(sigmas)
]

# dropout_p sweep against the SHARED draw stream (inverse-CDF coupling)
drops = [0.0, 0.2, 0.4, 0.6]
dres = api.fit(
    gd(), data, transport="allreduce", steps=STEPS,
    executor="mesh+sweep", faults=FaultPlan(seed=11, straggler=1),
    sweep={"dropout_p": jnp.asarray(drops)},
)
dtraj = np.asarray(dres.trajectory)
dledgers = dres.ledger if isinstance(dres.ledger, list) else [dres.ledger]
dropout_rows = [
    {
        "dropout_p": p,
        "final_loss": float(dtraj[i, -1]),
        "uplink_bytes": int(dledgers[i].uplink_bytes),
    }
    for i, p in enumerate(drops)
]

# fault overhead on the plain mesh path: faulted vs fault-free warm fit
def mesh_fit(faults=None):
    return api.fit(gd(), data, transport="allreduce", steps=STEPS,
                   executor="mesh", faults=faults)
dt_clean = timed(lambda: mesh_fit())
dt_faulted = timed(lambda: mesh_fit(plan))

# one compiled program across seeds: masks are jit arguments
clear_program_cache()
mesh_fit(FaultPlan(seed=1, dropout_p=0.3, straggler=1, quorum=3))
mesh_fit(FaultPlan(seed=2, dropout_p=0.3, straggler=1, quorum=3))
cache = program_cache_stats()

# traced faulted fit -> RunReport markdown for the sidecar
tracer = Tracer()
traced = api.fit(gd(), data, transport="allreduce", steps=STEPS,
                 executor="mesh", faults=plan, wire="dp:1.0,0.05",
                 tracer=tracer, trace="phases")
run_report_md = RunReport.from_fit(traced, tracer=tracer).to_markdown()

out = {
    "run_report_md": run_report_md,
    "workload": {"K": K, "Nk": NK, "n": N, "steps": STEPS},
    "fault_plan": plan.describe(),
    "env": {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": jax.devices()[0].device_kind,
        "num_devices": jax.device_count(),
    },
    "dp_frontier": frontier,
    "dropout_sweep": dropout_rows,
    "timings": {
        "frontier_wall_s": dt_frontier,
        "mesh_clean_wall_s": dt_clean,
        "mesh_faulted_wall_s": dt_faulted,
        "faulted_over_clean": dt_faulted / dt_clean,
    },
    "program_cache_across_seeds": cache,
}
print(json.dumps(out))
""" % {"steps": STEPS}


def run(rows):
    src = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env, timeout=900,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench_faults subprocess failed: {proc.stderr[-2000:]}"
        )
    results = json.loads(proc.stdout.strip().splitlines()[-1])

    for row in results["dp_frontier"]:
        rows.append((
            f"faults/dp_sigma={row['dp_sigma']}",
            results["timings"]["frontier_wall_s"] * 1e6 / STEPS,
            f"loss={row['final_loss']:.5f};up={row['uplink_bytes']}",
        ))
    for row in results["dropout_sweep"]:
        rows.append((
            f"faults/dropout_p={row['dropout_p']}",
            "-",
            f"loss={row['final_loss']:.5f};up={row['uplink_bytes']}",
        ))
    rows.append((
        "faults/mesh_overhead",
        results["timings"]["mesh_faulted_wall_s"] * 1e6 / STEPS,
        f"faulted_over_clean="
        f"{results['timings']['faulted_over_clean']:.3f}"
        f";programs={results['program_cache_across_seeds']['size']}",
    ))

    out_path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_faults.json",
    )
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_path}")
    return results


if __name__ == "__main__":
    rows: list = []
    res = run(rows)
    print("name,us_per_call,derived")
    for r in rows:
        print(",".join(str(c) for c in r))
