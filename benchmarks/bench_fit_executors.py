"""Executor comparison on a fixed GD workload: local (stacked scan) vs
mesh (shard_map node placement) vs sweep (vmapped S-scenario batch) vs
the composed mesh+sweep (scenario vmap inside the shard_map body).

Measures compiled wall-clock per fit and the ledger byte totals (which
must agree across local/mesh — placement changes WHERE the program runs,
not what crosses the wire), amortized per-scenario cost for the sweep
against S sequential fits, and the composed executor's throughput
against the local sweep (on ≥4 devices the sharded compute should win:
each device trains all S scenarios on 1/ndev of the nodes).  Writes
``BENCH_executors.json`` next to the repo root for the perf trajectory;
also pluggable into ``benchmarks.run`` (rows of
``name,us_per_call,derived``).

Run:
  PYTHONPATH=src python -m benchmarks.bench_fit_executors
  # more parallelism on CPU:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.bench_fit_executors
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.ml.linear import lsq_loss

K, NK, N = 8, 64, 256
STEPS = 200
LRS = (0.02, 0.05, 0.1, 0.2)


def _problem():
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(K, NK, N)))
    w = jnp.asarray(rng.normal(size=(N,)))
    y = jnp.einsum("kni,i->kn", X, w)
    return X, y


def _timed(fn, repeats=3):
    fn()  # compile + warm caches
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out.theta)
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(rows):
    X, y = _problem()
    data = (X, y)
    results = {
        "workload": {"K": K, "Nk": NK, "n": N, "steps": STEPS},
        "num_devices": jax.device_count(),
        # fake CPU devices oversubscribe the host's cores — the context
        # for reading the mesh rows (each shard is NOT a physical chip)
        "physical_cpus": os.cpu_count(),
        "executors": {},
    }

    for name, kwargs in [
        ("local", {"executor": "local"}),
        ("mesh", {"executor": "mesh"}),
        ("local_topk", {"executor": "local", "wire": "topk:0.1+ef"}),
        ("mesh_topk", {"executor": "mesh", "wire": "topk:0.1+ef"}),
    ]:
        dt, res = _timed(
            lambda kw=kwargs: api.fit(
                api.GradientDescent(lsq_loss, lr=0.05), data,
                transport="allreduce", steps=STEPS, **kw,
            )
        )
        results["executors"][name] = {
            "wall_s": dt,
            "total_bytes": res.ledger.total_bytes,
            "final_loss": float(res.trajectory[-1]),
        }
        rows.append((f"fit_executors/{name}", dt * 1e6 / STEPS,
                     f"{float(res.trajectory[-1]):.4f}"))

    # sweep: S scenarios in one executable vs S sequential fits
    sweep = api.SweepExecutor({"lr": jnp.asarray(LRS)})
    dt_sweep, res_sweep = _timed(
        lambda: api.fit(api.GradientDescent(lsq_loss, lr=0.05), data,
                        transport="allreduce", steps=STEPS, executor=sweep)
    )

    def _sequential():
        out = None
        for lr in LRS:
            out = api.fit(api.GradientDescent(lsq_loss, lr=lr), data,
                          transport="allreduce", steps=STEPS)
        return out

    dt_seq, _ = _timed(_sequential)
    results["executors"]["sweep"] = {
        "wall_s": dt_sweep,
        "scenarios": len(LRS),
        "wall_s_sequential_equivalent": dt_seq,
        "speedup_vs_sequential": dt_seq / dt_sweep,
        "total_bytes_per_scenario": res_sweep.ledger[0].total_bytes,
    }
    rows.append((f"fit_executors/sweep_S{len(LRS)}", dt_sweep * 1e6 / STEPS,
                 f"{dt_seq / dt_sweep:.2f}x_vs_seq"))

    # composed mesh+sweep: the same S scenarios with the scenario vmap
    # nested INSIDE the shard_map body — per-scenario results bit-exact
    # with S independent mesh fits, compute sharded over the devices.
    # Two baselines: sweep-local (the one-host alternative; the composed
    # mode should match or beat it when each shard is a real chip — on a
    # fake-device CPU host that oversubscribes the physical cores, the
    # per-step shard dispatch is the bottleneck and sweep-local keeps
    # the edge) and S sequential mesh fits (the mesh-resident
    # alternative the composition actually replaces: one executable
    # shares every psum across the S lanes, so this is the ~S× win).
    dt_comp, res_comp = _timed(
        lambda: api.fit(api.GradientDescent(lsq_loss, lr=0.05), data,
                        transport="allreduce", steps=STEPS,
                        executor="mesh+sweep",
                        sweep={"lr": jnp.asarray(LRS)})
    )
    assert (res_comp.ledger[0].total_bytes
            == res_sweep.ledger[0].total_bytes), "composed ledger drifted"

    def _sequential_mesh():
        out = None
        for lr in LRS:
            out = api.fit(api.GradientDescent(lsq_loss, lr=lr), data,
                          transport="allreduce", steps=STEPS,
                          executor="mesh")
        return out

    dt_seq_mesh, _ = _timed(_sequential_mesh)
    results["executors"]["mesh+sweep"] = {
        "wall_s": dt_comp,
        "scenarios": len(LRS),
        "wall_s_sweep_local": dt_sweep,
        "throughput_vs_sweep_local": dt_sweep / dt_comp,
        "wall_s_sequential_mesh_equivalent": dt_seq_mesh,
        "speedup_vs_sequential_mesh": dt_seq_mesh / dt_comp,
        "total_bytes_per_scenario": res_comp.ledger[0].total_bytes,
    }
    rows.append((f"fit_executors/mesh+sweep_S{len(LRS)}",
                 dt_comp * 1e6 / STEPS,
                 f"{dt_seq_mesh / dt_comp:.2f}x_vs_seq_mesh"))

    out_path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_executors.json",
    )
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_path}")
    return results


if __name__ == "__main__":
    rows: list = []
    res = run(rows)
    print("name,us_per_call,derived")
    for r in rows:
        print(",".join(str(c) for c in r))
    for name, stats in res["executors"].items():
        print(f"  {name}: {stats}")
