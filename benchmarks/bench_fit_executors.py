"""Executor comparison on a fixed GD workload: local (stacked scan) vs
mesh (shard_map node placement) vs sweep (vmapped S-scenario batch) vs
the composed mesh+sweep (scenario vmap inside the shard_map body).

Measures compiled wall-clock per fit — COLD (first call: trace + compile
+ run, program cache empty) and WARM (repeat call riding the executor
program cache) — and the ledger byte totals (which must agree across
local/mesh — placement changes WHERE the program runs, not what crosses
the wire), amortized per-scenario cost for the sweep against S
sequential fits, and the composed executor's throughput against the
local sweep (on ≥4 devices the sharded compute should win: each device
trains all S scenarios on 1/ndev of the nodes).

A separate per-phase decomposition isolates the three things a mesh
round actually does — the dense local step (grads + apply), the wire
encode (top-k select + EF residual), and the node-axis collective — as
standalone jitted loops over the same shapes, so any residual local↔mesh
gap can be attributed to a phase instead of guessed at.

Writes ``BENCH_executors.json`` next to the repo root for the perf
trajectory; also pluggable into ``benchmarks.run`` (rows of
``name,us_per_call,derived``).

Run:
  PYTHONPATH=src python -m benchmarks.bench_fit_executors
  # more parallelism on CPU:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.bench_fit_executors
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.api import executor as _exec
from repro.api.wire import make_wire
from repro.ml.linear import lsq_loss
from repro.telemetry import RunReport, Tracer

K, NK, N = 8, 64, 256
STEPS = 200
LRS = (0.02, 0.05, 0.1, 0.2)


def _problem():
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(K, NK, N)))
    w = jnp.asarray(rng.normal(size=(N,)))
    y = jnp.einsum("kni,i->kn", X, w)
    return X, y


def _timed(fn, repeats=3):
    """(cold_s, warm_s, out): cold = first call on an empty program cache
    (trace + compile + run); warm = best repeat riding the cache."""
    _exec.clear_program_cache()
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out.theta)
    cold = time.perf_counter() - t0
    warm = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out.theta)
        warm = min(warm, time.perf_counter() - t0)
    return cold, warm, out


def _timed_raw(prog, *args, repeats=3):
    out = jax.block_until_ready(prog(*args))  # compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = jax.block_until_ready(prog(*args))
        best = min(best, time.perf_counter() - t0)
    return best, out


def _phase_decomposition(data):
    """Wall-time of each per-round phase, isolated at the benchmark's
    own shapes and run STEPS times in a jitted loop:

    * ``local_step`` — per-node grads + stack-sum + apply (no wire, no
      mesh): the compute floor shared by every executor.
    * ``encode_topk`` — the compressed wire's stacked encode (top-k
      select + EF residual) on a fixed (K, n) message batch.
    * ``collective`` — a shard_map'd per-round psum over the node axis
      at the message shape: what placement itself adds.

    The sum approximates one mesh_topk fit; the differences attribute
    the local↔mesh gap to a phase.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    st = api.GradientDescent(lsq_loss, lr=0.05)
    theta0 = st.init_theta(data)

    def local_prog(th, d):
        def step(c, _):
            msgs, _s = st.local_updates(c, (), d, None)
            agg = jnp.sum(msgs, axis=0)  # the stack reduction, no mesh
            c2, _s = st.apply_update(c, agg, (), d)
            return c2, ()

        return jax.lax.scan(step, th, None, length=STEPS)[0]

    t_local, _ = _timed_raw(jax.jit(local_prog), theta0, data)

    wire = make_wire("topk:0.1+ef")
    wst = wire.init_state(theta0, K, stacked=True)
    msgs = jnp.asarray(
        np.random.default_rng(1).normal(size=(K, theta0.size)),
        theta0.dtype,
    )

    def encode_prog(w0, m):
        def step(c, _):
            ws, acc = c
            ws, m_hat, _up = wire.encode_updates(ws, m, stacked=True)
            return (ws, acc + jnp.sum(m_hat)), ()  # consume: defeat DCE

        return jax.lax.scan(step, (w0, jnp.zeros(())), None, length=STEPS)[0]

    t_encode, _ = _timed_raw(jax.jit(encode_prog), wst, msgs)

    r = api.MeshExecutor().resolve()

    def coll_body(m):
        def step(c, _):
            return c + jax.lax.psum(jnp.sum(m, axis=0), r.axis), ()

        return jax.lax.scan(
            step, jnp.zeros(m.shape[1:], m.dtype), None, length=STEPS
        )[0]

    coll = jax.jit(
        shard_map(
            coll_body, mesh=r.mesh, in_specs=P(r.axis), out_specs=P(),
            check_rep=False,
        )
    )
    t_coll, _ = _timed_raw(coll, msgs)

    return {
        "steps": STEPS,
        "local_step_s": t_local,
        "encode_topk_s": t_encode,
        "collective_s": t_coll,
    }


def run(rows):
    X, y = _problem()
    data = (X, y)
    results = {
        "workload": {"K": K, "Nk": NK, "n": N, "steps": STEPS},
        "env": {
            "jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "device_kind": jax.devices()[0].device_kind,
            "num_devices": jax.device_count(),
            # fake CPU devices oversubscribe the host's cores — the
            # context for reading the mesh rows (each shard is NOT a
            # physical chip)
            "physical_cpus": os.cpu_count(),
        },
        "num_devices": jax.device_count(),
        "physical_cpus": os.cpu_count(),
        "executors": {},
    }

    for name, kwargs in [
        ("local", {"executor": "local"}),
        ("mesh", {"executor": "mesh"}),
        ("local_topk", {"executor": "local", "wire": "topk:0.1+ef"}),
        ("mesh_topk", {"executor": "mesh", "wire": "topk:0.1+ef"}),
    ]:
        cold, warm, res = _timed(
            lambda kw=kwargs: api.fit(
                api.GradientDescent(lsq_loss, lr=0.05), data,
                transport="allreduce", steps=STEPS, **kw,
            )
        )
        mj = res.metrics_json()  # JSON-safe view (drops carry, strings
        entry = {                # non-serializable engine objects)
            "wall_s": warm,
            "cold_wall_s": cold,
            "total_bytes": res.ledger.total_bytes,
            "final_loss": float(res.trajectory[-1]),
        }
        if "wire_kernel_hits" in mj:
            entry["wire_kernel_hits"] = mj["wire_kernel_hits"]
        results["executors"][name] = entry
        rows.append((f"fit_executors/{name}", warm * 1e6 / STEPS,
                     f"{float(res.trajectory[-1]):.4f}"))

    # per-phase decomposition of what one round actually does
    results["phases"] = _phase_decomposition(data)
    for ph in ("local_step", "encode_topk", "collective"):
        rows.append((f"fit_executors/phase_{ph}",
                     results["phases"][f"{ph}_s"] * 1e6 / STEPS, ""))

    # sweep: S scenarios in one executable vs S sequential fits
    sweep = api.SweepExecutor({"lr": jnp.asarray(LRS)})
    cold_sweep, dt_sweep, res_sweep = _timed(
        lambda: api.fit(api.GradientDescent(lsq_loss, lr=0.05), data,
                        transport="allreduce", steps=STEPS, executor=sweep)
    )

    def _sequential():
        out = None
        for lr in LRS:
            out = api.fit(api.GradientDescent(lsq_loss, lr=lr), data,
                          transport="allreduce", steps=STEPS)
        return out

    _, dt_seq, _ = _timed(_sequential)
    results["executors"]["sweep"] = {
        "wall_s": dt_sweep,
        "cold_wall_s": cold_sweep,
        "scenarios": len(LRS),
        "wall_s_sequential_equivalent": dt_seq,
        "speedup_vs_sequential": dt_seq / dt_sweep,
        "total_bytes_per_scenario": res_sweep.ledger[0].total_bytes,
    }
    rows.append((f"fit_executors/sweep_S{len(LRS)}", dt_sweep * 1e6 / STEPS,
                 f"{dt_seq / dt_sweep:.2f}x_vs_seq"))

    # composed mesh+sweep: the same S scenarios with the scenario vmap
    # nested INSIDE the shard_map body — per-scenario results bit-exact
    # with S independent mesh fits, compute sharded over the devices.
    # Two baselines: sweep-local (the one-host alternative; the composed
    # mode should match or beat it when each shard is a real chip — on a
    # fake-device CPU host that oversubscribes the physical cores, the
    # per-step shard dispatch is the bottleneck and sweep-local keeps
    # the edge) and S sequential mesh fits (the mesh-resident
    # alternative the composition actually replaces: one executable
    # shares every psum across the S lanes, so this is the ~S× win).
    cold_comp, dt_comp, res_comp = _timed(
        lambda: api.fit(api.GradientDescent(lsq_loss, lr=0.05), data,
                        transport="allreduce", steps=STEPS,
                        executor="mesh+sweep",
                        sweep={"lr": jnp.asarray(LRS)})
    )
    assert (res_comp.ledger[0].total_bytes
            == res_sweep.ledger[0].total_bytes), "composed ledger drifted"

    def _sequential_mesh():
        out = None
        for lr in LRS:
            out = api.fit(api.GradientDescent(lsq_loss, lr=lr), data,
                          transport="allreduce", steps=STEPS,
                          executor="mesh")
        return out

    _, dt_seq_mesh, _ = _timed(_sequential_mesh)
    results["executors"]["mesh+sweep"] = {
        "wall_s": dt_comp,
        "cold_wall_s": cold_comp,
        "scenarios": len(LRS),
        "wall_s_sweep_local": dt_sweep,
        "throughput_vs_sweep_local": dt_sweep / dt_comp,
        "wall_s_sequential_mesh_equivalent": dt_seq_mesh,
        "speedup_vs_sequential_mesh": dt_seq_mesh / dt_comp,
        "total_bytes_per_scenario": res_comp.ledger[0].total_bytes,
    }
    rows.append((f"fit_executors/mesh+sweep_S{len(LRS)}",
                 dt_comp * 1e6 / STEPS,
                 f"{dt_seq_mesh / dt_comp:.2f}x_vs_seq_mesh"))

    results["program_cache"] = _exec.program_cache_stats()

    # one traced mesh+topk fit → a RunReport markdown block in the
    # sidecar, so the perf trajectory carries the phase decomposition
    # (per-phase device times, per-hop collectives, cache state), not
    # just wall totals
    tracer = Tracer()
    res_traced = api.fit(
        api.GradientDescent(lsq_loss, lr=0.05), data,
        transport="allreduce", steps=STEPS, executor="mesh",
        wire="topk:0.1+ef", tracer=tracer, trace="phases",
    )
    results["run_report_md"] = RunReport.from_fit(
        res_traced, tracer=tracer
    ).to_markdown()

    out_path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_executors.json",
    )
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_path}")
    return results


if __name__ == "__main__":
    rows: list = []
    res = run(rows)
    print("name,us_per_call,derived")
    for r in rows:
        print(",".join(str(c) for c in r))
    for name, stats in res["executors"].items():
        print(f"  {name}: {stats}")
