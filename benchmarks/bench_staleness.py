"""Staleness sweep: the §5 algorithm generalized to delay D on a reduced LM
(derived column = final loss; SGD vs the paper's cited Adagrad [19])."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.staleness import delay_init, delay_push_pop, staleness_bound_lr
from repro.data import synthetic_lm_batches
from repro.models import transformer as tf
from repro.optim import adagrad, sgd
from repro.optim.optimizers import apply_updates


def run(rows):
    cfg = get_config("qwen2-1.5b").reduced().replace(vocab_size=256)
    params0 = tf.init_params(jax.random.key(0), cfg)
    grad_fn = jax.jit(
        jax.value_and_grad(lambda p, b: tf.loss_fn(p, cfg, b)[0])
    )
    steps = 40

    for opt_name, make_opt in [
        ("sgd", lambda lr: sgd(lr)),
        ("adagrad", lambda lr: adagrad(lr * 10)),
    ]:
        for D in (0, 1, 2, 4):
            opt = make_opt(staleness_bound_lr(3e-2, D))
            params = params0
            opt_state = opt.init(params)
            delay = delay_init(params, D) if D else None
            data = synthetic_lm_batches(1, 4, 32, cfg.vocab_size)
            t0 = time.perf_counter()
            last = 0.0
            for _ in range(steps):
                batch = next(data)
                l, g = grad_fn(params, batch)
                if D:
                    delay, g = delay_push_pop(delay, g)
                upd, opt_state = opt.update(g, opt_state, params)
                params = apply_updates(params, upd)
                last = float(l)
            dt = (time.perf_counter() - t0) * 1e6 / steps
            rows.append((f"staleness_lm/{opt_name}_D{D}", dt, f"{last:.4f}"))
