"""Multi-pod hierarchical execution: predicted vs measured byte split.

Runs the same GD workload under the flat mesh executor and the multipod
executor on a 2×4 ``("pod", "data")`` mesh of 8 fake CPU devices (forced
in a SUBPROCESS, since the XLA device count is fixed at jax init), then
reports three things side by side:

* the ledger's PREDICTED split — flat lump vs per-hop (intra-pod /
  inter-pod) decomposition, priced per byte;
* the MEASURED split — ``telemetry.hlo.collective_stats`` over the
  compiled hierarchical aggregate's HLO, with each collective attributed
  to a tier by its replica groups (per-device bytes);
* the equivalence check (theta bitwise flat ≡ hierarchical) and compiled
  wall-clock for both placements.

Writes ``BENCH_multipod.json`` next to the repo root; also pluggable into
``benchmarks.run`` (rows of ``name,us_per_call,derived``).

Run:
  PYTHONPATH=src python -m benchmarks.bench_multipod
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

STEPS = 200

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
)
os.environ["JAX_PLATFORMS"] = "cpu"
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro import api
from repro.api import executor as X
from repro.core.allreduce import hierarchical_allreduce
from repro.core.topology import calibrate_prices
from repro.ml.linear import lsq_loss
from repro.telemetry.hlo import collective_stats, mesh_pod_map

K, NK, N, STEPS = 8, 64, 256, %(steps)d

rng = np.random.default_rng(0)
Xs = jnp.asarray(rng.normal(size=(K, NK, N)))
w = jnp.asarray(rng.normal(size=(N,)))
y = jnp.einsum("kni,i->kn", Xs, w)
data = (Xs, y)

mesh = jax.make_mesh((2, 4), ("pod", "data"))


def timed(fn, repeats=3):
    out = fn()
    jax.block_until_ready(out.theta)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out.theta)
        best = min(best, time.perf_counter() - t0)
    return best, out


dt_flat, flat = timed(lambda: api.fit(
    api.GradientDescent(lsq_loss, lr=0.05), data, transport="allreduce",
    steps=STEPS, executor=api.MeshExecutor(mesh)))
dt_hier, hier = timed(lambda: api.fit(
    api.GradientDescent(lsq_loss, lr=0.05), data, transport="allreduce",
    steps=STEPS, executor=api.MultiPodExecutor(mesh)))

a, b = np.asarray(flat.theta), np.asarray(hier.theta)
bitwise = bool((a.view(np.uint32) == b.view(np.uint32)).all())

# measured: compiled HLO of the hierarchical aggregate on the real mesh
mpe = api.MultiPodExecutor(mesh)
r = mpe.resolve()
ctx = X.ExecContext(
    node_axis=r.axis, num_shards=r.num_shards, topology=r.topology,
    axis_sizes=tuple(mesh.shape[a] for a in r.axes),
)


def round_aggregate(stacked):
    with X.executing(ctx):
        return X.aggregate(stacked)


g = jax.jit(shard_map(
    round_aggregate, mesh=mesh, in_specs=P(r.axis), out_specs=P(),
    check_rep=False,
))
txt = g.lower(jnp.ones((K, N))).compile().as_text()
measured = collective_stats(txt, pod_of=mesh_pod_map(mesh))

# per-hop wall-time decomposition at the message shape: each hop's psum
# timed alone in a jitted shard_map loop — the measured cost ratio the
# calibrated prices should reflect
def hop_loop(axes):
    def body(v):
        def step(c, _):
            return c + jax.lax.psum(v[0], axes), ()
        return jax.lax.scan(
            step, jnp.zeros(v.shape[1:], v.dtype), None, length=STEPS
        )[0]
    return jax.jit(shard_map(
        body, mesh=mesh, in_specs=P(r.axis), out_specs=P(),
        check_rep=False,
    ))

msg = jnp.ones((K, N))
hop_times = {}
for hop in r.topology.hops:
    prog = hop_loop(hop.axes)
    jax.block_until_ready(prog(msg))
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(prog(msg))
        best = min(best, time.perf_counter() - t0)
    hop_times[hop.name] = best

# one-shot microbenchmark replacing the ×1/×10 default hop prices
prices = calibrate_prices(mesh)

# one traced hierarchical fit -> the RunReport markdown carried in the
# sidecar: per-hop bytes AND per-hop device times in one artifact
from repro.telemetry import RunReport, Tracer

tracer = Tracer()
traced = api.fit(
    api.GradientDescent(lsq_loss, lr=0.05), data, transport="allreduce",
    steps=STEPS, executor=api.MultiPodExecutor(mesh),
    wire="topk:0.1+ef", tracer=tracer, trace="phases",
)
run_report_md = RunReport.from_fit(traced, tracer=tracer).to_markdown()

out = {
    "run_report_md": run_report_md,
    "workload": {"K": K, "Nk": NK, "n": N, "steps": STEPS},
    "mesh": {"pod": 2, "data": 4},
    "env": {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": jax.devices()[0].device_kind,
        "num_devices": jax.device_count(),
    },
    "equivalence": {"theta_bitwise_flat_vs_hierarchical": bitwise},
    "predicted": {
        "flat": flat.ledger.summary(),
        "hierarchical": hier.ledger.summary(),
    },
    "measured_hlo_per_device": {
        "by_tier": measured.get("by_tier", {}),
        "total_bytes": measured["total_bytes"],
        "total_count": measured["total_count"],
    },
    "timings": {
        "flat_wall_s": dt_flat,
        "hierarchical_wall_s": dt_hier,
        "per_hop_collective_s": hop_times,
    },
    "calibrated_prices": {
        k: v for k, v in prices.items() if k != "seconds"
    } | {"seconds": prices["seconds"]},
}
print(json.dumps(out))
""" % {"steps": STEPS}


def run(rows):
    src = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env, timeout=900,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench_multipod subprocess failed: {proc.stderr[-2000:]}"
        )
    results = json.loads(proc.stdout.strip().splitlines()[-1])

    flat = results["predicted"]["flat"]
    hier = results["predicted"]["hierarchical"]
    split = {
        name: v["total_bytes"] for name, v in hier["by_hop"].items()
    }
    rows.append((
        "multipod/flat",
        results["timings"]["flat_wall_s"] * 1e6 / STEPS,
        f"total_bytes={flat['total_bytes']}",
    ))
    rows.append((
        "multipod/hierarchical",
        results["timings"]["hierarchical_wall_s"] * 1e6 / STEPS,
        f"intra={split.get('intra_pod', 0)};inter={split.get('inter_pod', 0)}"
        f";priced={hier['priced_cost']:.0f}",
    ))

    out_path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_multipod.json",
    )
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_path}")
    return results


if __name__ == "__main__":
    rows: list = []
    res = run(rows)
    print("name,us_per_call,derived")
    for r in rows:
        print(",".join(str(c) for c in r))
    print(json.dumps(res["measured_hlo_per_device"], indent=2))
