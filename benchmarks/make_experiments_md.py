"""Compose EXPERIMENTS.md from the dry-run JSONs + hand-written analysis.

  PYTHONPATH=src python benchmarks/make_experiments_md.py
"""

import glob
import json

from aggregate_dryrun import dryrun_table, load, roofline_table

HEADER = """# EXPERIMENTS — Revisiting Large Scale Distributed Machine Learning

Environment: CPU-only container (1 core); TPU v5e is the **target**
(197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI per chip), proven by
AOT lowering + compilation against 512 host devices.  Pallas kernels are
validated in interpret mode against pure-jnp oracles.

## §Paper-validation

Claims of the paper validated by `tests/` + `benchmarks/` (run
`PYTHONPATH=src python -m benchmarks.run` for the CSV):

| paper claim | result |
|---|---|
| §5 round-robin ≡ serial mini-batch GD | exact to float reassociation (tests/test_core_server.py, test_system.py — real LM gradients) |
| §5 async converges at the same rate | logistic: async 200 contacts ≈ sync loss (bench `async_vs_sync_logistic`); reduced LM: same ballpark |
| §5 literal θ_{t-1} (stale) handoff converges | bench `stale_round_robin`; ε-neighborhood tests |
| §5 + [19] Adagrad under staleness | staleness sweep D∈{0,1,2,4}: SGD degrades gracefully; **Adagrad degrades faster at D=4** (its accumulator absorbs stale variance) — an honest counterpoint to the Downpour intuition |
| §3.1 one-Allreduce L-BFGS [5] | 30 L-BFGS iters beat 30 GD iters at equal comm rounds |
| §3.1 privacy second-order stats [6] | exact OLS recovery; wire = K·(n²+n) numbers, 6.8 % of raw data in the healthcare example |
| §3.1/§3.2 ADMM consensus | LASSO matches centralized ISTA to 1e-3; consensus SVM reaches centralized accuracy |
| §3.2 cascade SVM [25] | SV set stabilizes in ≤3 rounds, accuracy = centralized, wire = 13.5 % of raw |
| §3.3 PoE overconfidence / gPoE & (g)BCM prior fallback | far-from-data variance ratio: PoE 1/K vs 1.0 for gPoE/BCM/gBCM (bench `gp_experts`) |
| §4.2 k-windows: high precision, limited recall | d=2: precision 1.00 / recall 0.94 |
| §4.2 k-windows degrades in high dimension | d=20: precision 0.66 / recall 0.71 |
| §4.2 naive distributed merge over-merges [60] | close blobs: centralized 3 clusters, naive merge 2 |
| beyond-paper: slot-aligned consensus k-means | survives maximally heterogeneous shards within 3 % of centralized inertia ([21] assumes homogeneous) |
| §1/§5 low-communication push | top-k 10 % + error feedback trains within ~7 % of uncompressed loss at 20 % wire; int8 at 25 % wire matches baseline |

## §Dry-run

Every (architecture × input shape) lowers AND compiles on the single-pod
16×16 mesh and the 2×16×16 multi-pod mesh: **78 ok + 2 documented skips
(whisper long_500k: 448-token decoder context by construction) = 80/80.**
Multi-pod proves the `pod` axis shards (gradient reduction and FSDP span
`(pod, data)`).

Memory notes:
* "fits 16G" uses XLA-CPU's `memory_analysis`, which is pessimistic for
  TPU: XLA-CPU upcasts bf16 weights to f32 before matmuls (the MXU
  consumes bf16 natively) and fuses less, so weight-heavy entries are
  inflated ~2-4×.  Entries marked N at ≤40 GiB generally fit on v5e after
  accounting for this; the giants are honestly over:
* deepseek-v3-671b train on ONE v5e-256 pod does not fit (params+opt
  alone = 16 GiB/chip in bf16 at 512 chips; DeepSeek themselves used 2048
  H800s).  The multi-pod mesh halves state per chip (58 GiB→ analytic
  ~24 GiB incl. CPU inflation) — a 4-pod mesh is the realistic training
  footprint; serve shapes fit.
"""

MID = """
## §Roofline

Method: XLA `cost_analysis()` counts scan bodies ONCE, so FLOPs/bytes/
collective bytes are extracted by **probe lowering** (`telemetry/
costprobe.py`): unrolled 1-and-2-layer variants at two batch sizes →
per-segment marginal costs → affine-in-batch extrapolation to the
production shape (sLSTM's time recurrence added analytically).  Hardware
constants: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI.  `useful` =
MODEL_FLOPS (6·N_active·D train / 2·N_active per decode token) over total
compiled FLOPs.  Caveat: `bytes accessed` on the CPU backend overstates a
fused TPU executable (limited fusion + f32 weight upcasts); absolute
memory terms are upper bounds, relative comparisons across configs are
the signal.

Reading the table: train/prefill shapes are memory-term-dominated under
this metric (activation + weight traffic); the interesting outliers are
the collective-bound pairs (deepseek-67b/jamba decode_32k: FSDP parameter
re-gathers — see hillclimb B; xlstm prefill: small model on a big TP
mesh) and deepseek-v3 train (both terms huge — hillclimb A).  Decode
`useful` ratios are near zero by construction: one token's 2·N FLOPs
against full-cache traffic — decode is bandwidth-bound, as expected.

NOTE: the MoE rows (olmoe, deepseek-v3, jamba) are the PAPER-FAITHFUL
baselines recorded before the dispatch-layout fix of hillclimb A; the
shipped `moe.py` includes the fix, so re-running the sweep reproduces the
improved numbers (tagged `a2a` JSONs; e.g. olmoe train memory 24.5→8.4 s,
deepseek-v3 train memory 355→169 s).
"""

PERF = """
## §Perf — hillclimbing log

Three pairs selected per the brief: worst roofline fraction
(**deepseek-v3-671b × train_4k**), most collective-bound
(**deepseek-67b × decode_32k**), most representative of the paper's
technique (**tinyllama-1.1b × train_4k** — pure data-parallel
central-server training; the collective term IS the paper's push/pull).
All numbers are per-device roofline terms from the probe-corrected
dry-run on the 16×16 mesh.

### Hillclimb C — tinyllama-1.1b × train_4k (the paper's setting)

Baseline (TP16 × DP16, remat full, microbatch 4): compute 0.226 s /
memory 5.34 s / collective 1.99 s (99.5 GB/dev) — dominant: memory.

1. **Hypothesis**: a 1.1B model needs no tensor parallelism; TP spends
   ~2 psums × 22 layers × fwd+bwd on 268 MB activations (≈ 47 GB) plus
   logits collectives, while pure DP over all 256 chips costs only the
   gradient all-reduce (4.4 GB fp32).  Params+opt replicated = 13 GiB,
   fits.  → **`--strategy dp`**: collective 1.99 → **0.088 s (22.6×)**,
   memory 5.34 → 2.64 s; measured collective bytes = 4.40 GB = exactly
   the fp32 gradient (napkin confirmed).  CONFIRMED.  Cost: args 12.3 GiB
   replicated → steady-state 17.5 GiB, marginally over budget.
2. **Hypothesis**: ZeRO-3 (`dp_fsdp`) removes the replicated state for a
   param all-gather (~4.4 GB fwd + 4.4 GB bwd) + grad reduce-scatter
   (~4.4 GB) ≈ 13 GB collectives — still 8× under baseline TP.
   → collective 0.25 s (12.5 GB — napkin confirmed), memory 4.43 s,
   steady-state **7.4 GiB** (fits).  CONFIRMED.
3. **Hypothesis**: fewer microbatches → fewer per-microbatch param
   re-gathers under ZeRO-3.  → REFUTED-BY-INSTRUMENTATION: the cost
   probes model the mb=1 path, so the collective estimate is
   mb-invariant; memory_analysis shows mb=1 also drops the fp32 grad
   accumulator → **6.6 GiB** steady state.  Recorded as a probe-harness
   limitation.

**Paper-faithful baseline**: TP+DP, sync allreduce = the paper's server
in its exact-aggregation limit — memory 5.34 s / collective 1.99 s.
**Beyond-paper optimized**: ZeRO-3 data-parallel — memory 4.43 s (1.2×)
/ collective 0.25 s (8×), dominant term down 17 %.  Additionally the
paper's own §5 top-k push (bench `compression`) cuts the remaining
gradient traffic 5× at ~7 % loss penalty — on this config that is
collective 0.25 → ~0.06 s (modeled from wire bytes; XLA has no sparse
all-reduce primitive, so this lever needs a custom collective on real
hardware).

### Hillclimb B — deepseek-67b × decode_32k (most collective-bound)

Baseline (TP16 × FSDP16 params, cache seq-sharded over model): compute
0.0012 s / memory 0.287 s / collective 0.336 s (16.8 GB/dev) — dominant:
collective; 19.7 GiB steady state (over).

1. **Hypothesis**: XLA all-gathers the seq-sharded KV cache; pin
   `kvseq` sharding through the attention compute (flash-decode
   locality).  → REFUTED: terms unchanged.  Per-layer probe breakdown
   showed the 365 MB/layer of all-gathers are **parameter un-shards**
   (lm_head `[8192,6400]`, FFN `[8192,1376]`…), not KV.
2. **Hypothesis** (from the refutation): FSDP at decode is pure waste —
   there is no optimizer state to shard; params should stay TP-only and
   never be gathered.  → **`--strategy serve`**: collective 0.336 →
   **0.0027 s (123×)**, collective bytes 16.8 GB → 136 MB; dominant term
   flips to memory (0.27 s).  CONFIRMED — and the lesson generalizes:
   `serve` strategy is now the recommended default for all decode/prefill
   shapes.  (memory_analysis rises to 41 GiB on the CPU backend because
   un-FSDP'd bf16 weights get f32-upcast copies before every dot — a
   backend artifact; analytic v5e footprint = 8.4 GiB bf16 params + 3.2
   GiB cache ≈ 12 GiB, fits.)
3. **Decomposition of the remaining memory term** (affine-in-batch probe
   fit, per layer): weight reads ≈ 776 MB/layer/step (batch-invariant)
   vs cache+activation ≈ 12.8 MB/row/layer.  At B=128 the cache term
   dominates (151 vs 72 GiB/device/step equivalents): ds67b serving at
   this batch is **KV-bandwidth-bound** → next levers are int8 KV cache
   (2× on the dominant share) or windowed attention; both noted as
   future work, neither implemented as they change numerics/semantics.

**Paper-faithful baseline**: collective-bound, 0.336 s.  **Beyond-paper
optimized**: serve-strategy TP-only params — collective 123× down,
bottleneck moved to the physics-bound cache reads.

### Hillclimb A — deepseek-v3-671b × train_4k (worst roofline fraction)

Baseline (TP16 experts + FSDP16, bf16 params+moments, remat full, mb 4):
compute 13.0 s / memory 355 s / collective 191 s — dominant: memory;
100.6 GiB steady state (does not fit one pod, see §Dry-run).

1. **Hypothesis**: 2-D expert parallelism (experts over model×data =
   1 expert-shard/device) eliminates FSDP re-gathers of the 654 B expert
   params.  → **REFUTED HARD**: collective 191 → 1716 s (9× worse), temp
   342 GiB.  With tokens sharded over `data` and experts over
   `(model,data)`, the dispatch buffer cannot keep batch sharded — the
   partitioner replicates the (B,E,C,d) buffer across the expert grid
   (token traffic ×16).  Lesson: EP grids must be co-designed with the
   dispatch resharding; naive 2-D EP is an anti-pattern under SPMD.
2. **Hypothesis**: remat `dots` (save dot outputs) cuts backward
   recompute traffic.  → PARTIALLY REFUTED: memory 355 → 346 s (−2.4 %),
   compute 13.0 → 11.2 s, useful 0.37 → 0.43, but temp 83 → 133 GiB.
   The memory term is not recompute-dominated.
3. **Hypothesis** (from the XLA "inefficient partition" warning): the
   MoE dispatch buffer is replicated-and-sliced instead of all-to-all'd;
   pinning `(batch→data, expert→model, ·, ·)` sharding constraints on
   both sides of the expert einsums forces the token-sized all-to-all.
   Validated on olmoe first (fast): memory 24.5 → **8.36 s (2.9×)**,
   collective 16.7 → **5.29 s (3.2×)**, temp 13.5 → 9.5 GiB.  Then on
   deepseek-v3 itself: memory 355 → **168.6 s (2.1×)**, collective 191 →
   **78.7 s (2.4×)**, compute unchanged (12.4 s).  CONFIRMED — the
   constraint ships in `moe.py` for every MoE arch.

**Paper-faithful baseline** vs **beyond-paper optimized** (deepseek-v3):
dominant memory term 2.1× down and collective 2.4× down from one layout
constraint; the ep2d refutation and the dispatch fix together are the
§Perf story: on TPU SPMD, MoE performance is decided by whether the
dispatch boundary reshards by all-to-all or by replication.

### Bonus measurements (budget beyond the three hillclimbs)

* **MLA absorbed decode** (minicpm3-4b × decode_32k, serve strategy): the
  paper-faithful MLA decode up-projects the whole cached latent to
  per-head K/V every step; the absorbed form (W_uk folded into the query,
  W_uv into the output — `--mla-absorb`, bit-exact per
  tests/test_decode_consistency.py) gives compute 0.0137 → **0.0003 s
  (46×)** and memory 0.151 → **0.046 s (3.3×)**.  This is DeepSeek's
  published inference optimization reproduced as a measured lever.
* **Jamba × train_4k with the MoE dispatch fix**: memory 347 → 274 s
  (1.27×), collective 108 → **37 s (2.9×)** — the hillclimb-A fix
  generalizes across MoE architectures.
* **Jamba × decode_32k with the serve strategy** — a scale boundary:
  collective 0.266 → **0.0019 s (140×)** as for ds67b, but the memory term
  rises 0.177 → 0.49 s and becomes dominant: a 398B model TP-sharded
  16-way reads ~50 GB/device of weights per decode step, more than the
  FSDP'd layout's local reads.  Conclusion: TP-only serving wins when
  params/TP-degree is small next to the cache traffic (≤67B here); at
  398B+, decode wants a wider model axis (more chips) or weight
  quantization — the roofline harness quantifies exactly where the
  crossover sits.

### Stop criterion

Hillclimbs ended when remaining candidates (int8 KV cache, sparse
all-reduce, sequence parallelism for activations) either change model
numerics or require collectives XLA does not expose — all documented
above as future levers with napkin estimates.
"""


def main():
    rows = load()
    ok = sum(1 for d in rows if d["status"] == "ok" and not d.get("tag"))
    with open("EXPERIMENTS.md", "w") as f:
        f.write(HEADER)
        f.write("\n### Single pod (16×16 = 256 chips), baselines\n\n")
        f.write(dryrun_table([d for d in rows if not d.get("tag")], "16x16"))
        f.write("\n\n### Multi-pod (2×16×16 = 512 chips), baselines\n\n")
        f.write(dryrun_table([d for d in rows if not d.get("tag")], "2x16x16"))
        f.write("\n")
        f.write(MID)
        f.write("\n")
        f.write(roofline_table(rows))
        f.write("\n")
        f.write(PERF)
    print("EXPERIMENTS.md written")


if __name__ == "__main__":
    main()
